//! Bench: native-engine train-step throughput, single- vs multi-thread,
//! plus the per-op time breakdown.
//!
//! Records the perf trajectory of the persistent-pool executor on two
//! fixed shapes — the DIANA ResNet-8/CIFAR-10 supernet (the acceptance
//! workload) and the DIANA MobileNetV1/CIFAR-10 supernet (whose 1×1
//! pointwise layers exercise the im2col-free conv fast path) — plus the
//! miniature test supernet, and emits `BENCH_native_train.json` at the
//! repo root so CI archives the numbers per commit. The JSON carries a
//! `per_op` section (im2col vs matmul vs batch-norm vs optimizer …)
//! from the feature-gated step profiler, so future kernel work starts
//! from measured breakdowns instead of guesses.
//!
//! Regression gate: when `BENCH_CHECK=1` (set by the CI job) the bench
//! compares the resnet8 single-thread *and* 4-thread steps/sec, the 1-
//! and 4-thread quantized evals/sec, the quantized 4-thread speedup
//! ratio, the blocked-vs-naive qmatmul ratio and the f32 train-step
//! 4-thread speedup ratio against the committed
//! `rust/benches/native_train.baseline.json` and exits non-zero on a
//! >10% regression on any. The absolute floors are conservative
//! (machines differ) — re-pin them from a CI run's emitted JSON
//! whenever the engine gets deliberately faster; the three `_min` ratio
//! floors are machine-independent (both numbers come from the same run
//! on the same machine) and carry the acceptance criteria.
//!
//! Since the Amdahl-sweep PR the JSON also carries
//! `train_speedup_4_threads` (renamed from `speedup_4_threads`) and
//! `serial_fraction` — the share of the profiled single-thread step in
//! the never-laned buckets (`theta`, `cost_model`, `elementwise`), i.e.
//! the Amdahl serial term the lane sweep cannot touch.
//!
//! Since the SIMD/quantization PRs the JSON also carries:
//!
//! * `kernels` — isolated GFLOP/s of the three matmul microkernels on a
//!   conv-like shape, scalar and (under `--features simd-kernels`) the
//!   register-tiled SIMD variants called directly;
//! * `qmatmul` — isolated integer-GEMM GOP/s at M=N=K=256 of the naive
//!   reference vs the blocked tier vs (simd builds) the widening-lane
//!   tier vs the prepacked-panel variants vs (`arch-kernels` builds,
//!   when the CPU features are detected) the arch-intrinsic tier, plus
//!   the best-tier speedup over naive and — only when an arch kernel
//!   actually dispatched — `qmatmul_arch_speedup_vs_simd`, its speedup
//!   over the best *portable packed* tier (gated ≥ 1.15 under
//!   `BENCH_CHECK=1`; on hosts without the features the `qmatmul_tier`
//!   tag proves the fallback and the gate is skipped);
//! * `cpu` / `qmatmul_tier` / `arch_kernels` — the detected CPU
//!   features (avx2/avx512vnni/neon/dotprod), the tier runtime dispatch
//!   picked, and whether the arch tier was compiled in — so bench
//!   artifacts from different runners are interpretable;
//! * `quantized_evals_per_sec_threads{1,4}` — evals/sec of the real
//!   int8/ternary integer-GEMM inference path (QuantNet built once,
//!   batch shards on the persistent pool) next to the tape's f32 eval
//!   on the same state and thread count, with a `per_op` entry pinning
//!   the per-lane `qmatmul` counter;
//! * `simd_speedup_threads1` (simd builds only) — single-thread resnet8
//!   train speedup of the SIMD kernels over the scalar reference,
//!   measured in one process via the runtime toggle.
//!
//! Since the packed-f32 PR the JSON also carries `matmul_packed` — the
//! packed-panel f32 tier vs the unpacked tier of the *same build* at
//! real training-GEMM shapes (the resnet8 3×3 stage and an mbv1
//! pointwise stage, not the synthetic 256³), with the weight operand
//! packed once outside the timed loop exactly as the step-scoped
//! weight-pack cache amortizes it — plus the headline
//! `matmul_packed_speedup` (gated ≥ 1.2 in-run under `BENCH_CHECK=1`
//! via the machine-independent `matmul_packed_speedup_min` floor) and a
//! `diana_resnet8_c10_unpacked` per-op breakdown recorded with the
//! packing toggle off, so CI can diff where the packed tier moves time
//! per commit.

use std::time::Duration;

use odimo::runtime::native::profile;
use odimo::runtime::{ModelBackend, NativeBackend, NativeOptions, StepHparams, WOptimizer};
use odimo::util::bench::bench;
use odimo::util::json::{parse, Value};

const ACCEPTANCE_VARIANT: &str = "diana_resnet8_c10";
const POINTWISE_VARIANT: &str = "diana_mbv1_c10";
/// allowed regression vs a committed baseline floor (10%)
const GATE_FACTOR: f64 = 0.9;

fn hp() -> StepHparams {
    StepHparams {
        lam: 1e-7,
        cost_sel: 0.0,
        lr_w: 1e-2,
        lr_th: 5e-2,
    }
}

fn build(variant: &str, threads: usize) -> NativeBackend {
    NativeBackend::build_with(
        variant,
        NativeOptions {
            threads,
            w_optimizer: WOptimizer::SgdMomentum,
        },
    )
    .expect("native variant")
}

/// Train-step throughput of `variant` at `threads` workers (steps/sec,
/// from the mean over a few seconds of timed steps after one warm step).
fn train_steps_per_sec(variant: &str, threads: usize, budget: Duration) -> f64 {
    let be = build(variant, threads);
    let m = be.manifest();
    let ds = odimo::datasets::SynthDataset::from_name(
        &m.dataset.name,
        m.dataset.hw,
        m.dataset.classes,
        1,
    );
    let (x, y) = ds.batch(odimo::datasets::Split::Train, 0, m.dataset.batch);
    let mut state = be.init_state(0).expect("init");
    let r = bench(
        &format!("train_step {variant} t={threads} (batch {})", m.dataset.batch),
        1,
        budget,
        50,
        || {
            std::hint::black_box(be.train_step(&mut state, &x, &y, hp()).expect("step"));
        },
    );
    let sps = 1e9 / r.mean_ns;
    println!(
        "   -> {:.3} steps/s, {:.1} samples/s (arena growth after warmup: {})",
        sps,
        m.dataset.batch as f64 * sps,
        be.arena_grown()
    );
    sps
}

/// Eval-batch throughput of `variant` at 1 thread (evals/sec).
fn eval_batches_per_sec(variant: &str, budget: Duration) -> f64 {
    let be = NativeBackend::build(variant).expect("native variant");
    let m = be.manifest();
    let ds = odimo::datasets::SynthDataset::from_name(
        &m.dataset.name,
        m.dataset.hw,
        m.dataset.classes,
        2,
    );
    let (x, y) = ds.batch(odimo::datasets::Split::Val, 0, m.dataset.batch);
    let state = be.init_state(0).expect("init");
    let r = bench(&format!("eval_batch {variant} t=1"), 1, budget, 200, || {
        std::hint::black_box(be.eval_batch(&state, &x, &y).expect("eval"));
    });
    1e9 / r.mean_ns
}

/// Render the profiler snapshot accumulated over `steps` repetitions as
/// `{op: {share, ns_per_step, calls_per_step}}`, plus a stdout table.
fn snapshot_value(steps: usize) -> Value {
    let mut rows = profile::snapshot();
    rows.sort_by(|a, b| b.total_ns.cmp(&a.total_ns));
    let total: u64 = rows.iter().map(|r| r.total_ns).sum();
    if rows.is_empty() {
        println!("   (profiler compiled out — rebuilt without `op-profile`)");
    }
    let fields: Vec<(&str, Value)> = rows
        .iter()
        .map(|r| {
            let share = r.total_ns as f64 / total.max(1) as f64;
            println!(
                "   {:<12} {:>5.1}%  {:>12.0} ns/step",
                r.op.name(),
                100.0 * share,
                r.total_ns as f64 / steps as f64
            );
            (
                r.op.name(),
                Value::obj(vec![
                    ("share", Value::num(share)),
                    ("ns_per_step", Value::num(r.total_ns as f64 / steps as f64)),
                    (
                        "calls_per_step",
                        Value::num(r.calls as f64 / steps as f64),
                    ),
                ]),
            )
        })
        .collect();
    Value::obj(fields)
}

/// Per-op breakdown of `steps` profiled single-thread train steps:
/// `{op: {share, ns_per_step, calls_per_step}}`, plus stdout table.
fn per_op_breakdown(variant: &str, steps: usize) -> Value {
    let be = build(variant, 1);
    let m = be.manifest();
    let ds = odimo::datasets::SynthDataset::from_name(
        &m.dataset.name,
        m.dataset.hw,
        m.dataset.classes,
        3,
    );
    let (x, y) = ds.batch(odimo::datasets::Split::Train, 0, m.dataset.batch);
    let mut state = be.init_state(0).expect("init");
    // one unprofiled warm step so arena growth stays out of the numbers
    be.train_step(&mut state, &x, &y, hp()).expect("warm step");
    profile::reset();
    profile::set_enabled(true);
    for _ in 0..steps {
        be.train_step(&mut state, &x, &y, hp()).expect("profiled step");
    }
    profile::set_enabled(false);
    println!("-- per-op breakdown: {variant} ({steps} steps, t=1) --");
    snapshot_value(steps)
}

/// Per-op breakdown of profiled quantized evals — pins the `qmatmul`
/// counter (the integer-GEMM share of a deployed forward).
fn per_op_quantized(variant: &str, evals: usize) -> Value {
    let be = NativeBackend::build(variant).expect("native variant");
    let m = be.manifest();
    let ds = odimo::datasets::SynthDataset::from_name(
        &m.dataset.name,
        m.dataset.hw,
        m.dataset.classes,
        5,
    );
    let (x, y) = ds.batch(odimo::datasets::Split::Val, 0, m.dataset.batch);
    let state = be.init_state(0).expect("init");
    let qnet = be.quantize(&state).expect("quantize");
    qnet.eval_batch(&x, &y).expect("warm eval");
    profile::reset();
    profile::set_enabled(true);
    for _ in 0..evals {
        qnet.eval_batch(&x, &y).expect("profiled eval");
    }
    profile::set_enabled(false);
    println!("-- per-op breakdown: {variant} quantized eval ({evals} evals, t=1) --");
    snapshot_value(evals)
}

/// Quantized-inference throughput at `threads` pool workers: evals/sec
/// of the int8/ternary integer-GEMM path next to the tape's f32 eval on
/// the same state and thread count. The `QuantNet` is built once,
/// outside the timed loop — deploy-style (requantizing per batch was
/// the bug the eval loop used to have), and runs its batch shards on
/// the backend's persistent pool.
fn quantized_eval_per_sec(variant: &str, threads: usize, budget: Duration) -> (f64, f64) {
    let be = build(variant, threads);
    let m = be.manifest();
    let ds = odimo::datasets::SynthDataset::from_name(
        &m.dataset.name,
        m.dataset.hw,
        m.dataset.classes,
        4,
    );
    let (x, y) = ds.batch(odimo::datasets::Split::Val, 0, m.dataset.batch);
    let state = be.init_state(0).expect("init");
    let rf = bench(
        &format!("eval_batch {variant} f32 t={threads}"),
        1,
        budget,
        200,
        || {
            std::hint::black_box(be.eval_batch(&state, &x, &y).expect("eval"));
        },
    );
    let qnet = be.quantize(&state).expect("quantize");
    let rq = bench(
        &format!("eval_batch {variant} quantized t={threads}"),
        1,
        budget,
        200,
        || {
            std::hint::black_box(qnet.eval_batch(&x, &y).expect("quantized eval"));
        },
    );
    (1e9 / rf.mean_ns, 1e9 / rq.mean_ns)
}

/// Isolated integer-GEMM tiers at M=N=K=256 (the acceptance shape):
/// GOP/s of the naive reference, the blocked scalar tier, the packed
/// variants and — under `simd-kernels` / `arch-kernels` — the
/// widening-lane and arch-intrinsic tiers, called directly. Returns the
/// JSON section, the best-tier speedup over naive (acceptance metric:
/// ≥ 3x) and, when an arch kernel actually dispatched, its speedup over
/// the best *portable packed* tier (acceptance metric: ≥ 1.15 — `None`
/// means the dispatch provably fell back and no arch gate applies).
fn qmatmul_gops() -> (Value, f64, Option<f64>) {
    use odimo::runtime::native::qkernels;
    let (m, k, n) = (256usize, 256usize, 256usize);
    let fill = |len: usize, seed: u64| -> Vec<i8> {
        let mut st = seed;
        (0..len)
            .map(|_| {
                st = st
                    .wrapping_mul(6364136223846793005)
                    .wrapping_add(1442695040888963407);
                // codes in [-127, 127], like production quantizers: no
                // -128, so the x86 arch tiers are eligible to dispatch
                ((st >> 40) as i64 % 255 - 127) as i8
            })
            .collect()
    };
    let a = fill(m * k, 7);
    let b = fill(n * k, 8);
    let pb = qkernels::pack_b(&b, k, n);
    let mut c = vec![0i32; m * n];
    let ops = 2.0 * (m * k * n) as f64;
    let budget = Duration::from_millis(400);
    println!("-- qmatmul integer GOP/s (m=k=n={m}) --");
    let mut fields: Vec<(&str, Value)> = Vec::new();
    let mut run = |key: &'static str, f: &dyn Fn(&mut [i32])| -> f64 {
        let r = bench(key, 2, budget, 400, || {
            f(std::hint::black_box(&mut c));
        });
        let g = ops / r.mean_ns;
        println!("   {key:<24} {g:>7.2} GOP/s");
        fields.push((key, Value::num(g)));
        g
    };
    let naive = run("qmatmul_naive_gops", &|c| {
        qkernels::qmatmul_bt_into_naive(&a, &b, c, m, k, n)
    });
    let blocked = run("qmatmul_blocked_gops", &|c| {
        qkernels::qmatmul_bt_into_blocked(&a, &b, c, m, k, n)
    });
    let mut best = blocked;
    #[cfg(feature = "simd-kernels")]
    {
        let simd = run("qmatmul_simd_gops", &|c| {
            qkernels::qmatmul_bt_into_simd(&a, &b, c, m, k, n)
        });
        best = best.max(simd);
    }
    // packed drive: same tiers streaming prepacked panels (what the
    // QuantNet actually runs — the arch speedup is measured against the
    // best *portable packed* tier, so packing gains don't inflate it)
    let packed_blocked = run("qmatmul_packed_blocked_gops", &|c| {
        qkernels::qmatmul_bt_packed_into_blocked(&a, &pb, c, m)
    });
    #[cfg(feature = "simd-kernels")]
    let portable_best = {
        let packed_simd = run("qmatmul_packed_simd_gops", &|c| {
            qkernels::qmatmul_bt_packed_into_simd(&a, &pb, c, m)
        });
        packed_blocked.max(packed_simd)
    };
    #[cfg(not(feature = "simd-kernels"))]
    let portable_best = packed_blocked;
    best = best.max(portable_best);
    #[cfg(feature = "arch-kernels")]
    let arch_speedup: Option<f64> = {
        let mut probe = vec![0i32; m * n];
        if qkernels::qmatmul_bt_packed_into_arch(&a, &pb, &mut probe, m) {
            let arch = run("qmatmul_arch_gops", &|c| {
                let _ = qkernels::qmatmul_bt_packed_into_arch(&a, &pb, c, m);
            });
            best = best.max(arch);
            let sp = arch / portable_best;
            println!("   -> arch tier vs best portable packed: {sp:.2}x");
            fields.push(("qmatmul_arch_speedup_vs_simd", Value::num(sp)));
            Some(sp)
        } else {
            println!("   -> arch tier not dispatched on this host (fallback proven)");
            None
        }
    };
    #[cfg(not(feature = "arch-kernels"))]
    let arch_speedup: Option<f64> = None;
    let speedup = best / naive;
    println!("   -> best tier vs naive: {speedup:.2}x");
    fields.push(("qmatmul_speedup_vs_naive", Value::num(speedup)));
    (Value::obj(fields), speedup, arch_speedup)
}

/// Isolated GFLOP/s of the three matmul microkernels on a conv-like
/// shape — scalar references and (under `simd-kernels`) the SIMD tiles,
/// called directly so dispatch and threading stay out of the numbers.
fn kernel_gflops() -> Value {
    use odimo::runtime::native::tensor;
    // conv-like shape: a 32×32 output map of one image (m = 1024 patch
    // rows), 3×3×32 patches (k = 288), 64 output channels
    let (m, k, n) = (1024usize, 288usize, 64usize);
    let fill = |len: usize, seed: u64| -> Vec<f32> {
        let mut st = seed;
        (0..len)
            .map(|_| {
                st = st
                    .wrapping_mul(6364136223846793005)
                    .wrapping_add(1442695040888963407);
                ((st >> 40) as f32 / (1u64 << 24) as f32) - 0.5
            })
            .collect()
    };
    let flops = 2.0 * (m * k * n) as f64;
    let budget = Duration::from_millis(400);
    let mut fields: Vec<(&str, Value)> = Vec::new();
    println!("-- kernel GFLOP/s (m={m} k={k} n={n}) --");
    let push = |fields: &mut Vec<(&str, Value)>, key: &'static str, mean_ns: f64| {
        let g = flops / mean_ns;
        println!("   {key:<24} {g:>7.2} GFLOP/s");
        fields.push((key, Value::num(g)));
    };
    {
        let a = fill(m * k, 1);
        let b = fill(k * n, 2);
        let mut c = vec![0.0f32; m * n];
        let r = bench("matmul scalar", 2, budget, 400, || {
            tensor::matmul_into_scalar(&a, &b, std::hint::black_box(&mut c), m, k, n);
        });
        push(&mut fields, "matmul_scalar_gflops", r.mean_ns);
        #[cfg(feature = "simd-kernels")]
        {
            let r = bench("matmul simd", 2, budget, 400, || {
                tensor::simd::matmul_into(&a, &b, std::hint::black_box(&mut c), m, k, n);
            });
            push(&mut fields, "matmul_simd_gflops", r.mean_ns);
        }
    }
    {
        let a = fill(m * k, 3);
        let b = fill(n * k, 4);
        let mut c = vec![0.0f32; m * n];
        let r = bench("matmul_bt scalar", 2, budget, 400, || {
            tensor::matmul_bt_into_scalar(&a, &b, std::hint::black_box(&mut c), m, k, n);
        });
        push(&mut fields, "matmul_bt_scalar_gflops", r.mean_ns);
        #[cfg(feature = "simd-kernels")]
        {
            let r = bench("matmul_bt simd", 2, budget, 400, || {
                tensor::simd::matmul_bt_into(&a, &b, std::hint::black_box(&mut c), m, k, n);
            });
            push(&mut fields, "matmul_bt_simd_gflops", r.mean_ns);
        }
    }
    {
        let a = fill(m * k, 5);
        let b = fill(m * n, 6);
        let mut c = vec![0.0f32; k * n];
        let r = bench("matmul_at scalar", 2, budget, 400, || {
            tensor::matmul_at_into_scalar(&a, &b, std::hint::black_box(&mut c), m, k, n);
        });
        push(&mut fields, "matmul_at_scalar_gflops", r.mean_ns);
        #[cfg(feature = "simd-kernels")]
        {
            let r = bench("matmul_at simd", 2, budget, 400, || {
                tensor::simd::matmul_at_into(&a, &b, std::hint::black_box(&mut c), m, k, n);
            });
            push(&mut fields, "matmul_at_simd_gflops", r.mean_ns);
        }
    }
    Value::obj(fields)
}

/// Packed-panel f32 tier at real training-GEMM shapes: in-run speedup
/// of the packed drive over the unpacked tier of the same build (scalar
/// vs scalar, simd vs simd — the bit-identity pairing), with the weight
/// operand packed once *outside* the timed loop, mirroring how the
/// step-scoped weight-pack cache amortizes packing across a step's
/// shards and fwd/bwd GEMMs. Shapes are layer GEMMs, not 256³: the
/// resnet8 3×3 stage (m=1024 patch rows, k=288 fan-in, n=64 channels),
/// an mbv1 pointwise stage (m=1024, k=128, n=256) — both the Bᵀ forward
/// orientation, the training hot path — and one B-layout backward/dX
/// shape. Returns the JSON section and the headline speedup (best
/// Bᵀ-orientation ratio).
fn matmul_packed_gflops() -> (Value, f64) {
    use odimo::runtime::native::tensor;
    let fill = |len: usize, seed: u64| -> Vec<f32> {
        let mut st = seed;
        (0..len)
            .map(|_| {
                st = st
                    .wrapping_mul(6364136223846793005)
                    .wrapping_add(1442695040888963407);
                ((st >> 40) as f32 / (1u64 << 24) as f32) - 0.5
            })
            .collect()
    };
    let budget = Duration::from_millis(400);
    let mut fields: Vec<(&str, Value)> = Vec::new();
    println!("-- packed f32 tier at layer shapes --");
    // the unpacked comparison tier of this build (what the engine ran
    // before the packed tier existed)
    #[cfg(feature = "simd-kernels")]
    let (bt_unpacked, mm_unpacked): (
        &dyn Fn(&[f32], &[f32], &mut [f32], usize, usize, usize),
        &dyn Fn(&[f32], &[f32], &mut [f32], usize, usize, usize),
    ) = (
        &|a, b, c, m, k, n| tensor::simd::matmul_bt_into(a, b, c, m, k, n),
        &|a, b, c, m, k, n| tensor::simd::matmul_into(a, b, c, m, k, n),
    );
    #[cfg(not(feature = "simd-kernels"))]
    let (bt_unpacked, mm_unpacked): (
        &dyn Fn(&[f32], &[f32], &mut [f32], usize, usize, usize),
        &dyn Fn(&[f32], &[f32], &mut [f32], usize, usize, usize),
    ) = (
        &|a, b, c, m, k, n| tensor::matmul_bt_into_scalar(a, b, c, m, k, n),
        &|a, b, c, m, k, n| tensor::matmul_into_scalar(a, b, c, m, k, n),
    );
    let mut bt_shape = |tag: &str, keys: [&'static str; 3], m: usize, k: usize, n: usize| -> f64 {
        let flops = 2.0 * (m * k * n) as f64;
        let a = fill(m * k, 11);
        let b = fill(n * k, 12);
        let mut pb = vec![0.0f32; tensor::bt_packed_len(k, n)];
        tensor::pack_bt_into(&b, k, n, &mut pb);
        let mut c = vec![0.0f32; m * n];
        let ru = bench(&format!("matmul_bt unpacked {tag}"), 2, budget, 400, || {
            bt_unpacked(&a, &b, std::hint::black_box(&mut c), m, k, n);
        });
        let rp = bench(&format!("matmul_bt packed {tag}"), 2, budget, 400, || {
            tensor::matmul_bt_packed_into(&a, &pb, std::hint::black_box(&mut c), m, k, n);
        });
        let ratio = ru.mean_ns / rp.mean_ns;
        println!(
            "   {tag:<8} m={m} k={k} n={n}: {:.2} -> {:.2} GFLOP/s ({ratio:.2}x)",
            flops / ru.mean_ns,
            flops / rp.mean_ns
        );
        fields.push((keys[0], Value::num(flops / ru.mean_ns)));
        fields.push((keys[1], Value::num(flops / rp.mean_ns)));
        fields.push((keys[2], Value::num(ratio)));
        ratio
    };
    let r8 = bt_shape(
        "r8conv",
        [
            "bt_r8conv_unpacked_gflops",
            "bt_r8conv_packed_gflops",
            "bt_r8conv_speedup",
        ],
        1024,
        288,
        64,
    );
    let pw = bt_shape(
        "mbv1pw",
        [
            "bt_mbv1pw_unpacked_gflops",
            "bt_mbv1pw_packed_gflops",
            "bt_mbv1pw_speedup",
        ],
        1024,
        128,
        256,
    );
    // one B-layout shape (the backward/dX and FC-forward orientation)
    {
        let (m, k, n) = (1024usize, 256usize, 128usize);
        let flops = 2.0 * (m * k * n) as f64;
        let a = fill(m * k, 13);
        let b = fill(k * n, 14);
        let mut pb = vec![0.0f32; tensor::mm_packed_len(k, n)];
        tensor::pack_mm_into(&b, k, n, &mut pb);
        let mut c = vec![0.0f32; m * n];
        let ru = bench("matmul unpacked dX", 2, budget, 400, || {
            mm_unpacked(&a, &b, std::hint::black_box(&mut c), m, k, n);
        });
        let rp = bench("matmul packed dX", 2, budget, 400, || {
            tensor::matmul_packed_into(&a, &pb, std::hint::black_box(&mut c), m, k, n);
        });
        let ratio = ru.mean_ns / rp.mean_ns;
        println!(
            "   mm_dx    m={m} k={k} n={n}: {:.2} -> {:.2} GFLOP/s ({ratio:.2}x)",
            flops / ru.mean_ns,
            flops / rp.mean_ns
        );
        fields.push(("mm_dx_unpacked_gflops", Value::num(flops / ru.mean_ns)));
        fields.push(("mm_dx_packed_gflops", Value::num(flops / rp.mean_ns)));
        fields.push(("mm_dx_speedup", Value::num(ratio)));
    }
    let headline = r8.max(pw);
    println!("   -> packed f32 tier vs unpacked (best bt shape): {headline:.2}x");
    fields.push(("matmul_packed_speedup", Value::num(headline)));
    (Value::obj(fields), headline)
}

/// Amdahl serial term of a profiled breakdown: the summed share of the
/// buckets no kernel lane ever touches — `theta`, `cost_model`,
/// `elementwise` (the never-laned set documented in the lane-attribution
/// section of `runtime/native/profile`). Everything else either runs on
/// lanes already or is a serial remnant of a laned op, so this is the
/// floor the parallelization sweep is squeezing. The new `pack` bucket
/// (packed-panel relayouts) is per-lane/per-shard work and deliberately
/// stays out of this serial set.
fn serial_fraction(per_op: &Value) -> f64 {
    ["theta", "cost_model", "elementwise"]
        .iter()
        .filter_map(|op| per_op.get(op))
        .filter_map(|v| v.f64_of("share").ok())
        .sum()
}

/// `BENCH_CHECK=1` gate: fail on a >10% regression vs a committed floor.
fn gate(label: &str, measured: f64, baseline: &Value, key: &str) -> bool {
    let floor = baseline
        .f64_of(key)
        .unwrap_or_else(|_| panic!("baseline field {key}"));
    let min_ok = GATE_FACTOR * floor;
    if measured < min_ok {
        eprintln!(
            "BENCH REGRESSION: {label} {measured:.3} is more than 10% below \
             the committed baseline {floor:.3} (floor {min_ok:.3})"
        );
        false
    } else {
        println!("   -> baseline gate ok: {label} {measured:.3} >= {GATE_FACTOR} x {floor:.3}");
        true
    }
}

fn main() {
    println!("== native train-step bench (persistent-pool executor) ==");

    // trajectory entries: the miniature supernet, train + eval paths
    let tiny_sps = train_steps_per_sec("trident_tiny_tiny", 1, Duration::from_secs(1));
    let tiny_eval_sps = eval_batches_per_sec("trident_tiny_tiny", Duration::from_secs(1));

    // acceptance shape: single- vs multi-thread on the resnet8 supernet
    let s1 = train_steps_per_sec(ACCEPTANCE_VARIANT, 1, Duration::from_secs(4));
    let s4 = train_steps_per_sec(ACCEPTANCE_VARIANT, 4, Duration::from_secs(4));
    let speedup = s4 / s1;
    println!("   -> 4-thread speedup on {ACCEPTANCE_VARIANT}: {speedup:.2}x");

    // simd builds: re-run single-thread with the scalar reference via the
    // runtime toggle, so one process records the SIMD speedup directly
    #[cfg(feature = "simd-kernels")]
    let simd_speedup_t1 = Some({
        odimo::runtime::native::tensor::set_simd_enabled(false);
        let scalar_s1 = train_steps_per_sec(ACCEPTANCE_VARIANT, 1, Duration::from_secs(4));
        odimo::runtime::native::tensor::set_simd_enabled(true);
        let sp = s1 / scalar_s1;
        println!(
            "   -> simd-kernels single-thread speedup on {ACCEPTANCE_VARIANT}: {sp:.2}x"
        );
        sp
    });
    #[cfg(not(feature = "simd-kernels"))]
    let simd_speedup_t1: Option<f64> = None;

    // pointwise-dominated shape: covers the 1x1 im2col-free fast path
    let m1 = train_steps_per_sec(POINTWISE_VARIANT, 1, Duration::from_secs(4));
    let m4 = train_steps_per_sec(POINTWISE_VARIANT, 4, Duration::from_secs(4));
    println!(
        "   -> 4-thread speedup on {POINTWISE_VARIANT}: {:.2}x",
        m4 / m1
    );

    // isolated microkernel throughput (scalar vs simd, no dispatch)
    let kernels = kernel_gflops();

    // isolated integer-GEMM tiers (naive vs blocked vs simd vs packed
    // vs arch)
    let (qmatmul, qmatmul_speedup, qmatmul_arch_speedup) = qmatmul_gops();

    // packed f32 training tier: in-run packed-vs-unpacked ratio at
    // layer shapes, weight packed once outside the loop (cache steady
    // state)
    let (matmul_packed, packed_speedup) = matmul_packed_gflops();

    // quantized inference: the deploy path next to the tape's f32 eval,
    // single- and 4-thread (batch shards on the persistent pool)
    let (tiny_f32_eps, tiny_q_eps) =
        quantized_eval_per_sec("trident_tiny_tiny", 1, Duration::from_secs(1));
    let (r8_f32_eps, r8_q_eps) =
        quantized_eval_per_sec(ACCEPTANCE_VARIANT, 1, Duration::from_secs(2));
    let (r8_f32_eps4, r8_q_eps4) =
        quantized_eval_per_sec(ACCEPTANCE_VARIANT, 4, Duration::from_secs(2));
    let q_speedup4 = r8_q_eps4 / r8_q_eps;
    println!(
        "   -> quantized vs f32 eval throughput on {ACCEPTANCE_VARIANT}: {:.2}x (t=1), \
         {:.2}x (t=4); quantized 4-thread speedup {q_speedup4:.2}x",
        r8_q_eps / r8_f32_eps,
        r8_q_eps4 / r8_f32_eps4
    );

    // per-op breakdowns (profiled separately so probes never skew timings)
    let per_op_resnet8 = per_op_breakdown(ACCEPTANCE_VARIANT, 2);
    // same breakdown with the packing toggle off (an op-build-time
    // choice, so each profiled step sees a consistent state) — the CI
    // per-op-diff job renders the packed-vs-unpacked diff from the pair
    odimo::runtime::native::tensor::set_packing_enabled(false);
    let per_op_resnet8_unpacked = per_op_breakdown(ACCEPTANCE_VARIANT, 2);
    odimo::runtime::native::tensor::set_packing_enabled(true);
    let per_op_mbv1 = per_op_breakdown(POINTWISE_VARIANT, 2);
    let per_op_qeval = per_op_quantized(ACCEPTANCE_VARIANT, 4);
    let serial_frac = serial_fraction(&per_op_resnet8);
    println!(
        "   -> serial fraction on {ACCEPTANCE_VARIANT}: {:.1}% \
         (theta + cost_model + elementwise, the never-laned buckets)",
        100.0 * serial_frac
    );

    // emit the trajectory record
    let cpu = Value::obj(
        odimo::runtime::native::tensor::arch::cpu_features()
            .iter()
            .map(|&(k, v)| (k, Value::Bool(v)))
            .collect(),
    );
    let qmatmul_tier = odimo::runtime::native::QTier::detect().name();
    println!("   -> detected qmatmul tier: {qmatmul_tier}");
    let mut fields = vec![
        ("variant", Value::str(ACCEPTANCE_VARIANT)),
        ("simd_kernels", Value::Bool(cfg!(feature = "simd-kernels"))),
        ("arch_kernels", Value::Bool(cfg!(feature = "arch-kernels"))),
        ("cpu", cpu),
        ("qmatmul_tier", Value::str(qmatmul_tier)),
        ("threads1_steps_per_sec", Value::num(s1)),
        ("threads4_steps_per_sec", Value::num(s4)),
        ("train_speedup_4_threads", Value::num(speedup)),
        ("serial_fraction", Value::num(serial_frac)),
        ("mbv1_variant", Value::str(POINTWISE_VARIANT)),
        ("mbv1_threads1_steps_per_sec", Value::num(m1)),
        ("mbv1_threads4_steps_per_sec", Value::num(m4)),
        ("tiny_steps_per_sec", Value::num(tiny_sps)),
        ("tiny_eval_per_sec", Value::num(tiny_eval_sps)),
        ("kernels", kernels),
        ("matmul_packed", matmul_packed),
        ("matmul_packed_speedup", Value::num(packed_speedup)),
        ("qmatmul", qmatmul),
        ("quantized_evals_per_sec_threads1", Value::num(r8_q_eps)),
        ("quantized_evals_per_sec_threads4", Value::num(r8_q_eps4)),
        ("quantized_speedup_4_threads", Value::num(q_speedup4)),
        ("quantized_eval_f32_per_sec", Value::num(r8_f32_eps)),
        ("quantized_eval_f32_per_sec_threads4", Value::num(r8_f32_eps4)),
        ("quantized_eval_f32_ratio", Value::num(r8_q_eps / r8_f32_eps)),
        ("tiny_quantized_eval_per_sec", Value::num(tiny_q_eps)),
        ("tiny_quantized_eval_f32_per_sec", Value::num(tiny_f32_eps)),
        (
            "per_op",
            Value::obj(vec![
                ("diana_resnet8_c10", per_op_resnet8),
                ("diana_resnet8_c10_unpacked", per_op_resnet8_unpacked),
                ("diana_mbv1_c10", per_op_mbv1),
                ("diana_resnet8_c10_quantized_eval", per_op_qeval),
            ]),
        ),
    ];
    if let Some(sp) = simd_speedup_t1 {
        fields.push(("simd_speedup_threads1", Value::num(sp)));
    }
    let out = Value::obj(fields);
    let path = odimo::repo_root().join("BENCH_native_train.json");
    std::fs::write(&path, out.to_string_pretty()).expect("write bench json");
    println!("   -> wrote {}", path.display());

    // regression gate (CI sets BENCH_CHECK=1): f32 train floors, the
    // quantized eval floors, and two machine-independent ratio floors
    // (blocked-vs-naive qmatmul, quantized 4-thread speedup)
    if std::env::var("BENCH_CHECK").as_deref() == Ok("1") {
        let base_path = odimo::repo_root().join("rust/benches/native_train.baseline.json");
        let text = std::fs::read_to_string(&base_path).expect("committed bench baseline");
        let base = parse(&text).expect("baseline json");
        let mut checks = vec![
            gate("single-thread resnet8", s1, &base, "threads1_steps_per_sec"),
            gate("4-thread resnet8", s4, &base, "threads4_steps_per_sec"),
            gate(
                "1-thread quantized evals",
                r8_q_eps,
                &base,
                "quantized_evals_per_sec_threads1",
            ),
            gate(
                "4-thread quantized evals",
                r8_q_eps4,
                &base,
                "quantized_evals_per_sec_threads4",
            ),
            gate(
                "quantized 4-thread speedup",
                q_speedup4,
                &base,
                "quantized_speedup_4_threads_min",
            ),
            gate(
                "qmatmul best tier vs naive",
                qmatmul_speedup,
                &base,
                "qmatmul_speedup_vs_naive_min",
            ),
            gate(
                "train 4-thread speedup",
                speedup,
                &base,
                "train_speedup_4_threads_min",
            ),
            gate(
                "packed f32 tier vs unpacked",
                packed_speedup,
                &base,
                "matmul_packed_speedup_min",
            ),
        ];
        // the arch gate only applies when an arch kernel actually
        // dispatched — on hosts without the required CPU features the
        // tier tag in the JSON proves the fallback and no gate fires
        if let Some(sp) = qmatmul_arch_speedup {
            checks.push(gate(
                "qmatmul arch tier vs best portable packed",
                sp,
                &base,
                "qmatmul_arch_speedup_vs_simd_min",
            ));
        }
        if checks.iter().any(|ok| !ok) {
            std::process::exit(1);
        }
    }
}
