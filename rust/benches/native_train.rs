//! Bench: native-engine train-step throughput.
//!
//! Seeds the perf trajectory for the pure-Rust backend: one full
//! forward + backward + SGD update per sample, on the miniature test
//! supernet and on the paper-scale DIANA ResNet-20/CIFAR-10 supernet,
//! plus the eval-mode forward for comparison. Built (not run) by the CI
//! `cargo bench --no-run` gate.

use odimo::runtime::{ModelBackend, NativeBackend, StepHparams};
use odimo::util::bench::quick;

fn main() {
    println!("== native train-step bench ==");
    let hp = StepHparams {
        lam: 1e-7,
        cost_sel: 0.0,
        lr_w: 1e-2,
        lr_th: 5e-2,
    };

    for variant in ["trident_tiny_tiny", "diana_resnet20_c10"] {
        let be = NativeBackend::build(variant).expect("native variant");
        let m = be.manifest();
        let ds = odimo::datasets::SynthDataset::from_name(
            &m.dataset.name,
            m.dataset.hw,
            m.dataset.classes,
            1,
        );
        let (x, y) = ds.batch(odimo::datasets::Split::Train, 0, m.dataset.batch);
        let mut state = be.init_state(0).expect("init");
        // one warm step outside the timer (allocator warmup)
        be.train_step(&mut state, &x, &y, hp).expect("step");
        let r = quick(&format!("train_step {variant} (batch {})", m.dataset.batch), || {
            std::hint::black_box(be.train_step(&mut state, &x, &y, hp).expect("step"));
        });
        println!(
            "   -> {:.1} samples/s",
            m.dataset.batch as f64 / (r.mean_ns / 1e9)
        );
        quick(&format!("eval_batch {variant}"), || {
            std::hint::black_box(be.eval_batch(&state, &x, &y).expect("eval"));
        });
    }
}
