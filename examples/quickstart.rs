//! Quickstart: the end-to-end ODiMO flow on one variant.
//!
//! Runs the full three-phase search at a single λ on the DIANA
//! ResNet-20/CIFAR-10 supernet, discretizes the mapping, deploys it on
//! both SoC simulators and prints the outcome next to the All-8bit
//! baseline. Uses the native pure-Rust training engine by default, so it
//! works straight from a checkout; if `make artifacts` has been run the
//! XLA backend is picked up automatically.
//!
//! ```bash
//! cargo run --release --offline --example quickstart
//! # quicker: QUICKSTART_FAST=0.1 cargo run --release --example quickstart
//! ```

use anyhow::Result;

use odimo::config::ExperimentConfig;
use odimo::coordinator::{odimo as phases, run_baseline, Baseline, Trainer};
use odimo::runtime::ModelBackend;

fn main() -> Result<()> {
    let root = odimo::repo_root();
    let artifacts = root.join("artifacts");
    let fast: f64 = std::env::var("QUICKSTART_FAST")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.25);

    println!("== ODiMO quickstart: diana_resnet20_c10, λ = 0.2 ==\n");
    let cfg = ExperimentConfig::for_variant("diana_resnet20_c10").scaled(fast);
    let tr = Trainer::create(&artifacts, cfg, None)?;
    println!("(backend: {})", tr.backend.backend_name());

    // --- warmup ---------------------------------------------------------
    let mut state = tr.init_state()?;
    println!("[1/4] warmup ({} epochs)", tr.cfg.warmup_epochs);
    phases::run_phase(
        &tr,
        &mut state,
        odimo::runtime::StepHparams {
            lam: 0.0,
            cost_sel: 0.0,
            lr_w: tr.cfg.lr_w,
            lr_th: 0.0,
        },
        tr.cfg.warmup_epochs,
        0,
        "warmup",
    )?;

    // --- search + final -------------------------------------------------
    println!("[2/4] search + final-training (λ = 0.2)");
    let rec = phases::search_and_finalize(&tr, &mut state, 0.2)?;

    // --- baseline for context -------------------------------------------
    println!("[3/4] all-8bit baseline");
    let base = run_baseline(&tr, Baseline::AllOn(0))?;

    // --- report ----------------------------------------------------------
    println!("\n[4/4] results (detailed SoC simulator):");
    for r in [&base, &rec] {
        println!(
            "  {:<12} acc {:>6.2}%  latency {:>7.3} ms  energy {:>8.2} uJ  \
             util {}  offload-ch {:>4.1}%",
            r.label,
            100.0 * r.test_acc,
            r.det_latency_ms,
            r.det_energy_uj,
            r.util_display(),
            100.0 * r.offload_frac,
        );
    }
    let speedup = base.det_latency_ms / rec.det_latency_ms;
    println!(
        "\nODiMO mapping is {:.2}x {} than All-8bit at Δacc = {:+.2}%",
        speedup.max(1.0 / speedup),
        if speedup >= 1.0 { "faster" } else { "slower" },
        100.0 * (rec.test_acc - base.test_acc),
    );
    println!("(per-layer breakdown: `repro exp fig8`)");
    Ok(())
}
