//! SoC explorer: the hardware substrate without any training.
//!
//! Walks representative ResNet/MobileNet layer geometries through both
//! SoC simulators, printing per-CU latency curves as a function of the
//! channel split, the min-latency split (what the Min-Cost baseline
//! picks), and the analytical-vs-detailed gap. Runs with no artifacts —
//! pure Rust.
//!
//! ```bash
//! cargo run --release --offline --example soc_explorer
//! ```

use odimo::report::ascii_table;
use odimo::soc::{analytical, detailed, Layer, LayerAssignment, LayerType, Mapping, Platform};

fn split_mapping(platform: Platform, layer: &Layer, n1: usize) -> Mapping {
    Mapping {
        platform,
        layers: vec![LayerAssignment {
            layer: layer.name.clone(),
            cu_of: (0..layer.cout)
                .map(|c| u8::from(c >= layer.cout - n1))
                .collect(),
        }],
    }
}

fn explore(platform: Platform, layer: &Layer) {
    let cus = platform.cus();
    println!(
        "\n-- {:?}: {} (cin {}, cout {}, {}x{} @{}x{}) --",
        platform, layer.name, layer.cin, layer.cout, layer.k, layer.k, layer.ox, layer.oy
    );
    let mut rows = Vec::new();
    let mut best = (u64::MAX, 0usize);
    for frac in [0.0, 0.25, 0.5, 0.75, 1.0] {
        let n1 = (layer.cout as f64 * frac) as usize;
        let m = split_mapping(platform, layer, n1);
        let a = analytical::execute(std::slice::from_ref(layer), &m, &[]);
        let d = detailed::execute(std::slice::from_ref(layer), &m, &[]);
        if a.total_cycles < best.0 {
            best = (a.total_cycles, n1);
        }
        rows.push(vec![
            format!("{}/{}", layer.cout - n1, n1),
            a.layers[0].per_cu[0].cycles.to_string(),
            a.layers[0].per_cu[1].cycles.to_string(),
            a.total_cycles.to_string(),
            d.total_cycles.to_string(),
            format!("{:.2}", a.energy_uj),
        ]);
    }
    let h0 = format!("{}ch/{}ch", cus[0].label(), cus[1].label());
    let h1 = format!("cyc {}", cus[0].label());
    let h2 = format!("cyc {}", cus[1].label());
    let headers: Vec<&str> = vec![&h0, &h1, &h2, "layer cyc (ana)", "layer cyc (det)", "E [uJ]"];
    println!("{}", ascii_table(&headers, &rows));
    // exhaustive min-cost split (what the Min-Cost baseline computes)
    let mut opt = (u64::MAX, 0usize);
    for n1 in 0..=layer.cout {
        let m = split_mapping(platform, layer, n1);
        let a = analytical::execute(std::slice::from_ref(layer), &m, &[]);
        if a.total_cycles < opt.0 {
            opt = (a.total_cycles, n1);
        }
    }
    println!(
        "   min-latency split: {} ch on {}, {} ch on {} ({} cycles)",
        layer.cout - opt.1,
        cus[0].label(),
        opt.1,
        cus[1].label(),
        opt.0
    );
}

fn main() {
    let resnet_layers = [
        Layer {
            name: "res-early".into(),
            ltype: LayerType::Conv,
            cin: 16,
            cout: 16,
            k: 3,
            ox: 32,
            oy: 32,
            stride: 1,
            searchable: true,
        },
        Layer {
            name: "res-late".into(),
            ltype: LayerType::Conv,
            cin: 64,
            cout: 64,
            k: 3,
            ox: 8,
            oy: 8,
            stride: 1,
            searchable: true,
        },
    ];
    for l in &resnet_layers {
        explore(Platform::Diana, l);
    }
    let mbv1 = Layer {
        name: "mb-block".into(),
        ltype: LayerType::Search,
        cin: 64,
        cout: 64,
        k: 3,
        ox: 8,
        oy: 8,
        stride: 1,
        searchable: true,
    };
    explore(Platform::Darkside, &mbv1);
    println!("\n(the detailed column is always above the analytical one — \
              that bias is the Table III 'error')");
}
