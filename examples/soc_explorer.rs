//! SoC explorer: the hardware substrate without any training.
//!
//! Walks representative ResNet/MobileNet layer geometries through the
//! simulators of every requested platform — by default all three
//! built-ins, including the JSON-defined tri-CU `trident` SoC — printing
//! per-CU latency curves as a function of the channel split, the
//! min-latency partition (what the Min-Cost baseline picks), and the
//! analytical-vs-detailed gap. Runs with no artifacts — pure Rust.
//!
//! ```bash
//! cargo run --release --offline --example soc_explorer            # all built-ins
//! cargo run --release --offline --example soc_explorer -- trident # one platform
//! ```

use odimo::coordinator::baselines::min_cost_counts;
use odimo::report::ascii_table;
use odimo::soc::{analytical, detailed, Layer, LayerAssignment, LayerType, Mapping, Platform};

/// `n_off` of the channels leave column 0, round-robin over the rest.
fn split_mapping(platform: Platform, layer: &Layer, n_off: usize) -> Mapping {
    Mapping {
        platform,
        layers: vec![LayerAssignment::offload_round_robin(
            &layer.name,
            layer.cout,
            n_off,
            platform.n_cus(),
        )],
    }
}

fn explore(platform: Platform, layer: &Layer) {
    let cus = platform.cus();
    println!(
        "\n-- {}: {} (cin {}, cout {}, {}x{} @{}x{}) --",
        platform.name(),
        layer.name,
        layer.cin,
        layer.cout,
        layer.k,
        layer.k,
        layer.ox,
        layer.oy
    );
    let mut rows = Vec::new();
    for frac in [0.0, 0.25, 0.5, 0.75, 1.0] {
        let n_off = (layer.cout as f64 * frac) as usize;
        let m = split_mapping(platform, layer, n_off);
        let a = analytical::execute(std::slice::from_ref(layer), &m, &[]);
        let d = detailed::execute(std::slice::from_ref(layer), &m, &[]);
        let mut row = vec![a.layers[0]
            .per_cu
            .iter()
            .map(|c| c.channels.to_string())
            .collect::<Vec<_>>()
            .join("/")];
        for c in &a.layers[0].per_cu {
            row.push(c.cycles.to_string());
        }
        row.push(a.total_cycles.to_string());
        row.push(d.total_cycles.to_string());
        row.push(format!("{:.2}", a.energy_uj));
        rows.push(row);
    }
    let mut headers: Vec<String> = vec![format!(
        "ch {}",
        cus.iter().map(|c| c.name.as_str()).collect::<Vec<_>>().join("/")
    )];
    for cu in cus {
        headers.push(format!("cyc {}", cu.name));
    }
    headers.push("layer cyc (ana)".into());
    headers.push("layer cyc (det)".into());
    headers.push("E [uJ]".into());
    let header_refs: Vec<&str> = headers.iter().map(|h| h.as_str()).collect();
    println!("{}", ascii_table(&header_refs, &rows));
    // the min-cost partition (what the Min-Cost baseline computes)
    let counts = min_cost_counts(platform, layer, false);
    let m = Mapping {
        platform,
        layers: vec![{
            let mut cu_of = Vec::new();
            for (cu, &n) in counts.iter().enumerate() {
                cu_of.extend(std::iter::repeat(cu as u8).take(n));
            }
            LayerAssignment {
                layer: layer.name.clone(),
                cu_of,
            }
        }],
    };
    let a = analytical::execute(std::slice::from_ref(layer), &m, &[]);
    let parts: Vec<String> = counts
        .iter()
        .zip(cus)
        .map(|(n, cu)| format!("{n} ch on {}", cu.name))
        .collect();
    println!(
        "   min-latency partition: {} ({} cycles)",
        parts.join(", "),
        a.total_cycles
    );
}

fn main() {
    let requested: Vec<String> = std::env::args().skip(1).collect();
    let names: Vec<String> = if requested.is_empty() {
        odimo::soc::platform_names()
    } else {
        requested
    };
    let resnet_layers = [
        Layer {
            name: "res-early".into(),
            ltype: LayerType::Conv,
            cin: 16,
            cout: 16,
            k: 3,
            ox: 32,
            oy: 32,
            stride: 1,
            searchable: true,
        },
        Layer {
            name: "res-late".into(),
            ltype: LayerType::Conv,
            cin: 64,
            cout: 64,
            k: 3,
            ox: 8,
            oy: 8,
            stride: 1,
            searchable: true,
        },
    ];
    let mb_block = Layer {
        name: "mb-block".into(),
        ltype: LayerType::Search,
        cin: 64,
        cout: 64,
        k: 3,
        ox: 8,
        oy: 8,
        stride: 1,
        searchable: true,
    };
    for name in &names {
        let platform = match Platform::get(name) {
            Ok(p) => p,
            Err(e) => {
                eprintln!("skipping '{name}': {e}");
                continue;
            }
        };
        if platform.name() == "diana" {
            for l in &resnet_layers {
                explore(platform, l);
            }
        } else {
            explore(platform, &mb_block);
        }
    }
    println!(
        "\n(the detailed column is always above the analytical one — \
         that bias is the Table III 'error')"
    );
}
