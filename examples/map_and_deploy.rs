//! Map-and-deploy: from θ to silicon(-simulator), step by step.
//!
//! Demonstrates the deployment half of the stack on the Darkside
//! MobileNetV1 supernet: a short search, then the Eq. 6 contiguity check,
//! the Fig. 4 re-organization pass (permutations + per-CU sub-layers),
//! and execution on both the analytical model and the detailed
//! event-driven simulator.
//!
//! ```bash
//! cargo run --release --offline --example map_and_deploy
//! ```

use anyhow::Result;

use odimo::config::ExperimentConfig;
use odimo::coordinator::Trainer;
use odimo::mapping::reorganize;
use odimo::runtime::{ModelBackend, StepHparams};

fn main() -> Result<()> {
    let artifacts = odimo::repo_root().join("artifacts");
    let mut cfg = ExperimentConfig::for_variant("darkside_mbv1_c10").scaled(0.3);
    cfg.lambdas = vec![0.3];
    let tr = Trainer::create(&artifacts, cfg, None)?;
    println!("(backend: {})", tr.backend.backend_name());

    println!("== map_and_deploy: darkside_mbv1_c10 ==");
    let mut state = tr.init_state()?;
    let hp = StepHparams {
        lam: (0.3 / tr.manifest().cost_scale.latency_cycles) as f32,
        cost_sel: 0.0,
        lr_w: tr.cfg.lr_w,
        lr_th: tr.cfg.lr_th,
    };
    println!("[1/3] short joint search ({} epochs)", tr.cfg.search_epochs);
    for e in 0..tr.cfg.search_epochs {
        let m = tr.run_epoch(&mut state, hp, e)?;
        println!("   epoch {e}: loss {:.3} acc {:.3}", m.loss, m.acc);
    }

    println!("\n[2/3] discretize + reorganize (Fig. 4 pass)");
    let mapping = tr.discretize_all(&state)?;
    let reorg = reorganize(&mapping);
    for (asg, lr) in mapping.layers.iter().zip(&reorg.layers) {
        if !tr
            .manifest()
            .layers
            .iter()
            .any(|l| l.searchable && l.name == asg.layer)
        {
            continue;
        }
        if tr.kind == odimo::mapping::SearchKind::Split {
            // Eq. 6 split spaces are contiguous by construction; channel
            // spaces interleave and rely on the Fig. 4 reorg below
            assert!(asg.is_contiguous(), "Eq. 6 must keep splits contiguous");
        }
        assert!(lr.is_valid_permutation());
        let subs: Vec<String> = lr
            .sub_layers
            .iter()
            .map(|s| {
                format!(
                    "{}[{}..{})",
                    tr.platform.cus()[s.cu as usize].name,
                    s.start,
                    s.end
                )
            })
            .collect();
        println!("   {:<6} -> {}", asg.layer, subs.join(" ++ "));
    }

    println!("\n[3/3] deploy on both simulators");
    let (ana, det) = tr.simulate(&mapping);
    println!(
        "   analytical : {:>9} cycles  {:>8.2} uJ",
        ana.total_cycles, ana.energy_uj
    );
    println!(
        "   detailed   : {:>9} cycles  {:>8.2} uJ  ({:.3} ms @{}MHz, util {})",
        det.total_cycles,
        det.energy_uj,
        det.latency_ms,
        tr.platform.freq_mhz(),
        det.utilization
            .iter()
            .map(|u| format!("{:.0}%", 100.0 * u))
            .collect::<Vec<_>>()
            .join("/"),
    );
    println!(
        "   model underestimation: {:.1}% (this gap is what Table III quantifies)",
        100.0 * (det.total_cycles as f64 - ana.total_cycles as f64) / det.total_cycles as f64
    );
    Ok(())
}
