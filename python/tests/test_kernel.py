"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes (and via data, magnitudes); assert_allclose pins
the kernels to ref.py. This is the core correctness signal for the
compile path — the same kernels are baked into every AOT artifact.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    dw_conv3x3,
    effective_weights_fwd_kernel,
    effective_weights_ste,
    fake_quant_int8,
    fake_quant_ternary,
    matmul,
    matmul_kernel,
    ref,
)
from compile.kernels.fake_quant import ste_int8_rows, ste_ternary_rows

SETTINGS = dict(max_examples=12, deadline=None)


def rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


# ---------------------------------------------------------------------------
# fake quantizers
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(c=st.integers(1, 70), f=st.integers(1, 300), seed=st.integers(0, 2**31))
def test_fake_quant_int8_matches_ref(c, f, seed):
    w = rand(np.random.default_rng(seed), c, f)
    np.testing.assert_allclose(
        fake_quant_int8(w), ref.fake_quant_int8(w), rtol=1e-6, atol=1e-6)


@settings(**SETTINGS)
@given(c=st.integers(1, 70), f=st.integers(1, 300), seed=st.integers(0, 2**31))
def test_fake_quant_ternary_matches_ref(c, f, seed):
    w = rand(np.random.default_rng(seed), c, f)
    np.testing.assert_allclose(
        fake_quant_ternary(w), ref.fake_quant_ternary(w), rtol=1e-6, atol=1e-6)


def test_int8_idempotent():
    w = rand(np.random.default_rng(0), 16, 64)
    q1 = fake_quant_int8(w)
    q2 = fake_quant_int8(q1)
    np.testing.assert_allclose(q1, q2, rtol=1e-5, atol=1e-6)


def test_int8_levels_bounded():
    w = rand(np.random.default_rng(1), 8, 128) * 10
    q = np.asarray(fake_quant_int8(w))
    scale = np.abs(w).max(axis=1, keepdims=True) / 127.0
    levels = q / scale
    assert np.all(np.abs(levels) <= 127.0 + 1e-4)
    np.testing.assert_allclose(levels, np.round(levels), atol=1e-3)


def test_ternary_is_ternary():
    w = rand(np.random.default_rng(2), 8, 128)
    q = np.asarray(fake_quant_ternary(w))
    for row in q:
        vals = np.unique(np.round(row, 5))
        assert len(vals) <= 3, f"row has {len(vals)} distinct values"


def test_zero_weights_survive():
    w = jnp.zeros((4, 32), jnp.float32)
    np.testing.assert_array_equal(fake_quant_int8(w), w)
    np.testing.assert_array_equal(fake_quant_ternary(w), w)


def test_ste_gradients_are_identity():
    w = rand(np.random.default_rng(3), 6, 20)
    for fn in (ste_int8_rows, ste_ternary_rows):
        g = jax.grad(lambda x: jnp.sum(fn(x) * 2.0))(w)
        np.testing.assert_allclose(g, 2.0 * np.ones_like(w), rtol=1e-6)


# ---------------------------------------------------------------------------
# effective weights (Eq. 5)
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(c=st.integers(1, 70), f=st.integers(1, 200), seed=st.integers(0, 2**31))
def test_effective_weights_matches_ref(c, f, seed):
    rng = np.random.default_rng(seed)
    w = rand(rng, c, f)
    th = jax.nn.softmax(rand(rng, c, 2), axis=-1)
    weff, q8, qt = effective_weights_fwd_kernel(w, th)
    rweff, rq8, rqt = ref.effective_weights(w, th)
    np.testing.assert_allclose(weff, rweff, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(q8, rq8, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(qt, rqt, rtol=1e-5, atol=1e-6)


def test_effective_weights_one_hot_reduces_to_quantizer():
    rng = np.random.default_rng(7)
    w = rand(rng, 12, 45)
    th8 = jnp.stack([jnp.ones(12), jnp.zeros(12)], axis=1)
    tht = jnp.stack([jnp.zeros(12), jnp.ones(12)], axis=1)
    np.testing.assert_allclose(
        effective_weights_ste(w, th8), ref.fake_quant_int8(w), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(
        effective_weights_ste(w, tht), ref.fake_quant_ternary(w), rtol=1e-5, atol=1e-6)


def test_effective_weights_vjp():
    """STE backward: dW = upstream, dθ = <upstream, q_branch>."""
    rng = np.random.default_rng(8)
    w = rand(rng, 5, 11)
    th = jax.nn.softmax(rand(rng, 5, 2), axis=-1)
    g = rand(rng, 5, 11)
    _, vjp = jax.vjp(effective_weights_ste, w, th)
    dw, dth = vjp(g)
    np.testing.assert_allclose(dw, g, rtol=1e-6)
    _, q8, qt = ref.effective_weights(w, th)
    np.testing.assert_allclose(dth[:, 0], jnp.sum(g * q8, axis=1), rtol=2e-5, atol=1e-5)
    np.testing.assert_allclose(dth[:, 1], jnp.sum(g * qt, axis=1), rtol=2e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    m=st.integers(1, 150),
    k=st.integers(1, 150),
    n=st.integers(1, 150),
    seed=st.integers(0, 2**31),
)
def test_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, m, k)
    y = rand(rng, k, n)
    np.testing.assert_allclose(
        matmul_kernel(x, y), ref.matmul(x, y), rtol=1e-4, atol=1e-4)


def test_matmul_blocks_span_k_loop():
    """Shapes larger than one block exercise the K-accumulation loop."""
    rng = np.random.default_rng(11)
    x = rand(rng, 300, 300)
    y = rand(rng, 300, 130)
    np.testing.assert_allclose(
        matmul_kernel(x, y), ref.matmul(x, y), rtol=1e-4, atol=1e-3)


def test_matmul_gradients():
    rng = np.random.default_rng(12)
    x = rand(rng, 17, 23)
    y = rand(rng, 23, 9)
    gx, gy = jax.grad(lambda a, b: jnp.sum(matmul(a, b)), argnums=(0, 1))(x, y)
    ones = jnp.ones((17, 9), jnp.float32)
    np.testing.assert_allclose(gx, ref.matmul(ones, y.T), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gy, ref.matmul(x.T, ones), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# depthwise conv
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    b=st.integers(1, 3),
    hw=st.integers(3, 20),
    c=st.integers(1, 40),
    stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**31),
)
def test_dw_conv_matches_ref(b, hw, c, stride, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, b, hw, hw, c)
    k = rand(rng, 3, 3, c)
    np.testing.assert_allclose(
        dw_conv3x3(x, k, stride=stride),
        ref.dw_conv3x3(x, k, stride=stride),
        rtol=1e-5,
        atol=1e-5,
    )


def test_dw_conv_matches_lax():
    """Cross-check the oracle itself against lax.conv."""
    rng = np.random.default_rng(13)
    x = rand(rng, 2, 10, 10, 7)
    k = rand(rng, 3, 3, 7)
    import compile.layers as L
    np.testing.assert_allclose(
        ref.dw_conv3x3(x, k), L.dw_conv2d(x, k, 1), rtol=1e-5, atol=1e-5)
