"""AOT boundary tests: manifest schema, io-spec ↔ artifact consistency,
HLO-text emission. Artifact-dependent checks skip when `make artifacts`
has not run."""

import json
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import variants as V

ARTIFACTS = Path(__file__).resolve().parents[2] / "artifacts"


def test_to_hlo_text_emits_parseable_module():
    lowered = jax.jit(lambda x: (x * 2.0,)).lower(
        jnp.zeros((2, 2), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ROOT" in text


def test_leaf_specs_flatten_order():
    tree = {"a": jnp.zeros((2, 3)), "b": {"c": jnp.zeros((4,), jnp.int32)}}
    specs = aot._leaf_specs("params", tree)
    assert [s["name"] for s in specs] == ["params/a", "params/b/c"]
    assert specs[0]["shape"] == [2, 3]
    assert specs[1]["dtype"] == "i32"


@pytest.mark.skipif(not (ARTIFACTS / ".stamp").exists(),
                    reason="run `make artifacts` first")
@pytest.mark.parametrize("variant", ["diana_resnet20_c10", "darkside_mbv1_c10"])
def test_manifest_matches_registry(variant):
    m = json.loads((ARTIFACTS / f"{variant}.manifest.json").read_text())
    var = V.REGISTRY[variant]
    assert m["platform"] == var.platform
    assert m["dataset"]["batch"] == var.dataset.batch
    assert m["dataset"]["classes"] == var.dataset.classes
    # every function's HLO file exists and is non-trivial
    for fn, spec in m["functions"].items():
        p = ARTIFACTS / spec["file"]
        assert p.exists(), f"{variant}:{fn} missing {spec['file']}"
        assert p.stat().st_size > 1000
    # train state loops: every init output appears as a train input
    init_outs = [o["name"] for o in m["functions"]["init"]["outputs"]]
    train_ins = [i["name"] for i in m["functions"]["train"]["inputs"]]
    assert train_ins[: len(init_outs)] == init_outs
    # θ leaves exist for every searchable layer
    for layer in m["layers"]:
        if layer["searchable"]:
            assert f"params/{layer['name']}/theta" in train_ins


@pytest.mark.skipif(not (ARTIFACTS / ".stamp").exists(),
                    reason="run `make artifacts` first")
def test_cost_scale_positive():
    for mf in ARTIFACTS.glob("*.manifest.json"):
        m = json.loads(mf.read_text())
        assert m["cost_scale"]["latency_cycles"] > 0, mf.name
        assert m["cost_scale"]["energy_uj"] > 0, mf.name
