"""Training-step machinery: masked optimizers, loss decrease, phase
semantics (θ frozen at lr_θ=0), metric plumbing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import train as T
from compile import variants as V


@pytest.fixture(scope="module")
def tiny_setup():
    # smallest diana variant config, trimmed further for speed
    from compile import supernet_diana as DI
    var = V.Variant(
        "tiny", "diana", V.DatasetSpec("synth-cifar10", 16, 4, 8), "sgdm",
        DI.DianaConfig("tiny", 16, 8, (8,), 1, 4))
    fns = V.build_fns(var)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(8, 16, 16, 3)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, 4, size=(8,)).astype(np.int32))
    return var, fns, x, y


def scalars(lam=0.0, sel=0.0, lr_w=1e-2, lr_th=0.0):
    return (jnp.float32(lam), jnp.float32(sel), jnp.float32(lr_w),
            jnp.float32(lr_th))


def test_loss_decreases_on_fixed_batch(tiny_setup):
    var, (init_fn, train_fn, eval_fn, cost_fn), x, y = tiny_setup
    params, ow, ot = init_fn(0)
    jt = jax.jit(train_fn)
    losses = []
    for _ in range(12):
        params, ow, ot, m = jt(params, ow, ot, x, y, *scalars())
        losses.append(float(m[0]))
    assert losses[-1] < losses[0], f"loss did not decrease: {losses}"


def test_theta_frozen_when_lr_th_zero(tiny_setup):
    var, (init_fn, train_fn, *_), x, y = tiny_setup
    params, ow, ot = init_fn(0)
    th0 = np.asarray(params["stem"]["theta"])
    jt = jax.jit(train_fn)
    for _ in range(3):
        params, ow, ot, _ = jt(params, ow, ot, x, y, *scalars(lam=1e-6))
    np.testing.assert_array_equal(np.asarray(params["stem"]["theta"]), th0)


def test_theta_moves_when_searching(tiny_setup):
    var, (init_fn, train_fn, *_), x, y = tiny_setup
    params, ow, ot = init_fn(0)
    th0 = np.asarray(params["stem"]["theta"])
    jt = jax.jit(train_fn)
    for _ in range(3):
        params, ow, ot, _ = jt(params, ow, ot, x, y,
                               *scalars(lam=1e-5, lr_th=0.05))
    assert np.any(np.asarray(params["stem"]["theta"]) != th0)


def test_lambda_zero_reduces_to_task_loss(tiny_setup):
    var, (init_fn, train_fn, *_), x, y = tiny_setup
    params, ow, ot = init_fn(0)
    _, _, _, m = jax.jit(train_fn)(params, ow, ot, x, y, *scalars(lam=0.0))
    np.testing.assert_allclose(float(m[0]), float(m[1]), rtol=1e-6)


def test_bn_stats_update_without_gradient(tiny_setup):
    var, (init_fn, train_fn, *_), x, y = tiny_setup
    params, ow, ot = init_fn(0)
    m0 = np.asarray(params["stem"]["bn"]["mean"])
    params, ow, ot, _ = jax.jit(train_fn)(params, ow, ot, x, y, *scalars())
    m1 = np.asarray(params["stem"]["bn"]["mean"])
    assert np.any(m1 != m0), "BN running mean not updated"


def test_metrics_finite_and_ordered(tiny_setup):
    var, (init_fn, train_fn, eval_fn, cost_fn), x, y = tiny_setup
    params, ow, ot = init_fn(0)
    _, _, _, m = jax.jit(train_fn)(params, ow, ot, x, y, *scalars())
    m = np.asarray(m)
    assert m.shape == (5,)
    assert np.all(np.isfinite(m))
    assert 0.0 <= m[2] <= 1.0  # acc
    assert m[3] > 0 and m[4] > 0  # lat cycles, energy uJ
    ev = np.asarray(eval_fn(params, x, y))
    assert ev.shape == (2,)
    assert 0 <= ev[0] <= 8


def test_leaf_roles():
    from jax.tree_util import tree_flatten_with_path
    tree = {"l1": {"w": jnp.zeros(2), "theta": jnp.zeros(2),
                   "bn": {"mean": jnp.zeros(1), "var": jnp.ones(1),
                          "scale": jnp.ones(1), "bias": jnp.zeros(1)}}}
    roles = {T.path_str(p): T.leaf_role(p)
             for p, _ in tree_flatten_with_path(tree)[0]}
    assert roles["l1/w"] == "weight"
    assert roles["l1/theta"] == "theta"
    assert roles["l1/bn/mean"] == "bn_stat"
    assert roles["l1/bn/var"] == "bn_stat"
    assert roles["l1/bn/scale"] == "weight"


def test_adam_and_sgdm_differ(tiny_setup):
    """Same grads, different W optimizer ⇒ different updates."""
    var, (init_fn, train_fn, *_), x, y = tiny_setup
    import dataclasses
    var2 = V.Variant(var.name, var.platform, var.dataset, "adam", var.cfg,
                     var.search_kind)
    fns2 = V.build_fns(var2)
    p1, ow1, ot1 = init_fn(0)
    p2, ow2, ot2 = fns2[0](0)
    p1b, *_ = jax.jit(train_fn)(p1, ow1, ot1, x, y, *scalars())
    p2b, *_ = jax.jit(fns2[1])(p2, ow2, ot2, x, y, *scalars())
    w1 = np.asarray(p1b["stem"]["w"])
    w2 = np.asarray(p2b["stem"]["w"])
    assert not np.allclose(w1, w2)
