"""L2 supernet tests: shapes, one-hot reduction, gate contiguity (Eq. 6),
gradient flow to θ, and the variant registry."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import supernet_darkside as DS
from compile import supernet_diana as DI
from compile import variants as V

RNG = np.random.default_rng(0)


def x_batch(hw=32, b=2):
    return jnp.asarray(RNG.normal(size=(b, hw, hw, 3)).astype(np.float32))


@pytest.fixture(scope="module")
def diana_small():
    cfg = DI.DianaConfig("t", 32, 8, (8, 16), 1, 10)
    params = DI.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def darkside_small():
    cfg = DS.DarksideConfig("t", 32, 8, ((8, 1, 16), (16, 2, 32)), 10, 1.0)
    params = DS.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


# ---------------------------------------------------------------------------
# DIANA
# ---------------------------------------------------------------------------

def test_diana_shapes(diana_small):
    cfg, params = diana_small
    logits, new_bn, per_layer, fc_lat = DI.apply(params, x_batch(), cfg, True)
    assert logits.shape == (2, 10)
    assert len(per_layer) == len(DI.build_geoms(cfg)[0])
    assert float(fc_lat) > 0
    for name, lats, counts in per_layer:
        assert len(lats) == 2
        c = DI.build_geoms(cfg)[0]
        assert float(counts[0] + counts[1]) > 0


def test_diana_uniform_theta_splits_counts(diana_small):
    cfg, params = diana_small
    _, _, per_layer, _ = DI.apply(params, x_batch(), cfg, True)
    for name, lats, (n_d, n_a) in per_layer:
        np.testing.assert_allclose(float(n_d), float(n_a), rtol=1e-5)


def test_diana_one_hot_theta_is_pure_precision(diana_small):
    cfg, params = diana_small
    from compile.kernels import ref
    p2 = jax.tree_util.tree_map(lambda x: x, params)
    # force stem fully digital
    c = cfg.stem_width
    p2["stem"]["theta"] = jnp.stack(
        [20.0 * jnp.ones(c), -20.0 * jnp.ones(c)], axis=1)
    _, _, per_layer, _ = DI.apply(p2, x_batch(), cfg, True)
    name, lats, (n_d, n_a) = per_layer[0]
    assert float(n_d) > c - 1e-3
    assert float(n_a) < 1e-3


def test_diana_theta_receives_gradient(diana_small):
    cfg, params = diana_small

    def loss(p):
        logits, _, per_layer, _ = DI.apply(p, x_batch(), cfg, True)
        lat = sum(l[1][0] + l[1][1] for l in per_layer)
        return jnp.sum(logits**2) * 0.0 + lat

    g = jax.grad(loss)(params)
    gt = np.asarray(g["stem"]["theta"])
    assert np.any(gt != 0.0), "θ got no cost gradient"


def test_diana_prune_mode_single_cu():
    cfg = DI.DianaConfig("t", 32, 8, (8,), 1, 10, mode="prune")
    params = DI.init(jax.random.PRNGKey(0), cfg)
    logits, _, per_layer, _ = DI.apply(params, x_batch(), cfg, True)
    assert logits.shape == (2, 10)
    for _, lats, counts in per_layer:
        assert len(lats) == 1  # digital only


def test_diana_fixed_mode_has_no_theta():
    cfg = DI.DianaConfig("t", 32, 8, (8,), 1, 10, mode="fixed8")
    params = DI.init(jax.random.PRNGKey(0), cfg)
    assert "theta" not in params["stem"]
    logits, _, per_layer, _ = DI.apply(params, x_batch(), cfg, True)
    assert logits.shape == (2, 10)


# ---------------------------------------------------------------------------
# Darkside / Eq. 6 gate
# ---------------------------------------------------------------------------

def test_split_gate_monotone_and_bounded():
    for seed in range(5):
        theta = jnp.asarray(np.random.default_rng(seed).normal(size=17).astype(np.float32))
        g = np.asarray(DS.split_gate(theta, 16))
        assert g.shape == (16,)
        assert np.all(g >= -1e-6) and np.all(g <= 1 + 1e-6)
        assert np.all(np.diff(g) <= 1e-6), "gate must be non-increasing"


def test_split_gate_extremes():
    c = 8
    t_all_conv = jnp.zeros(c + 1).at[c].set(30.0)  # split = C
    g = np.asarray(DS.split_gate(t_all_conv, c))
    np.testing.assert_allclose(g, 1.0, atol=1e-6)
    t_all_dw = jnp.zeros(c + 1).at[0].set(30.0)  # split = 0
    g = np.asarray(DS.split_gate(t_all_dw, c))
    np.testing.assert_allclose(g, 0.0, atol=1e-6)


def test_darkside_shapes(darkside_small):
    cfg, params = darkside_small
    logits, new_bn, per_layer = DS.apply(params, x_batch(), cfg, True)
    assert logits.shape == (2, 10)
    # stem + 2*(search, pw) + fc
    assert len(per_layer) == 1 + 2 * 2 + 1


def test_darkside_theta_gradient(darkside_small):
    cfg, params = darkside_small

    def loss(p):
        _, _, per_layer = DS.apply(p, x_batch(), cfg, True)
        return sum(l[1][0] + l[1][1] for l in per_layer)

    g = jax.grad(loss)(params)
    assert np.any(np.asarray(g["blk0"]["theta"]) != 0.0)


def test_darkside_dwsep_mode():
    cfg = DS.DarksideConfig("t", 32, 8, ((8, 1, 16),), 10, 1.0,
                            search_mode="dw_vs_dwsep")
    params = DS.init(jax.random.PRNGKey(0), cfg)
    assert "w_pw_sep" in params["blk0"] and "w_conv" not in params["blk0"]
    logits, _, per_layer = DS.apply(params, x_batch(), cfg, True)
    assert logits.shape == (2, 10)


def test_darkside_layerwise_mode():
    cfg = DS.DarksideConfig("t", 32, 8, ((8, 1, 16),), 10, 1.0,
                            search_mode="layerwise")
    params = DS.init(jax.random.PRNGKey(0), cfg)
    assert params["blk0"]["theta"].shape == (2,)
    logits, _, _ = DS.apply(params, x_batch(), cfg, True)
    assert logits.shape == (2, 10)


def test_darkside_width_multiplier_scales():
    cfg1 = DS.DarksideConfig("t", 32, 8, ((8, 1, 16),), 10, 1.0)
    cfg2 = DS.DarksideConfig("t", 32, 8, ((8, 1, 16),), 10, 0.5)
    s1, _ = DS._scaled(cfg1)
    s2, _ = DS._scaled(cfg2)
    assert s2 == max(4, s1 // 2)


# ---------------------------------------------------------------------------
# variants registry
# ---------------------------------------------------------------------------

def test_registry_complete():
    expected = {
        "diana_resnet20_c10", "diana_resnet8_c100", "diana_resnet8_imgnet",
        "diana_resnet20_c10_prune", "darkside_mbv1_c10",
        "darkside_mbv1_c10_w050", "darkside_mbv1_c10_w025",
        "darkside_mbv1_c100", "darkside_mbv1_imgnet",
        "darkside_mbv1_c10_layerwise",
    }
    assert expected.issubset(set(V.REGISTRY))
    # every main variant has a _fixed twin for Table II
    for name in ["diana_resnet20_c10", "darkside_mbv1_c10",
                 "darkside_mbv1_imgnet"]:
        assert name + "_fixed" in V.REGISTRY


def test_layer_table_consistent_with_cost_rows():
    for name in ["diana_resnet20_c10", "darkside_mbv1_c10"]:
        var = V.REGISTRY[name]
        rows = V.layer_table(var)
        _, _, _, cost_fn = V.build_fns(var)
        params = (DI.init if var.platform == "diana" else DS.init)(
            jax.random.PRNGKey(0), var.cfg)
        mat, totals = cost_fn(params)
        assert mat.shape == (len(rows), 4), f"{name}: {mat.shape} vs {len(rows)}"
        assert float(totals[0]) > 0 and float(totals[1]) > 0
