"""Differentiable cost models (Eq. 3/4): closed-form checks, monotonicity,
smooth-max behaviour, and agreement with the constants file."""

import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import costs as C

HW = json.loads((Path(__file__).resolve().parents[2] / "hw" / "constants.json").read_text())


def geom(cin=16, cout=32, k=3, hw=16, ltype="conv"):
    return C.LayerGeom("t", ltype, cin, cout, k, hw, hw, 1, True)


def test_constants_match_file():
    assert C.HW == HW


def test_gate_limits():
    assert float(C.gate(0.0)) == 0.0
    assert float(C.gate(64.0)) > 0.99
    assert 0.0 < float(C.gate(0.5)) < 1.0


def test_smoothmax_approximates_max():
    a, b = jnp.float32(1000.0), jnp.float32(100.0)
    m = float(C.smoothmax([a, b]))
    assert 999.0 <= m <= 1001.0
    # symmetric
    assert abs(float(C.smoothmax([b, a])) - m) < 1e-3


def test_smoothmax_is_differentiable():
    g = jax.grad(lambda x: C.smoothmax([x, jnp.float32(10.0)]))(jnp.float32(100.0))
    assert np.isfinite(float(g))
    assert float(g) > 0.9  # dominant term gets ~all the gradient


@settings(max_examples=20, deadline=None)
@given(n1=st.floats(0, 64), n2=st.floats(0, 64))
def test_diana_models_monotone(n1, n2):
    lo, hi = sorted([n1, n2])
    g = geom()
    assert float(C.diana_digital_cycles(lo, g)) <= float(C.diana_digital_cycles(hi, g)) + 1e-3
    assert float(C.diana_analog_cycles(lo, g)) <= float(C.diana_analog_cycles(hi, g)) + 1e-3
    assert float(C.darkside_cluster_cycles(lo, g)) <= float(C.darkside_cluster_cycles(hi, g)) + 1e-3
    assert float(C.darkside_dwe_cycles(lo, g)) <= float(C.darkside_dwe_cycles(hi, g)) + 1e-3


def test_zero_channels_costs_nothing():
    g = geom()
    for fn in (C.diana_digital_cycles, C.diana_analog_cycles,
               C.darkside_cluster_cycles, C.darkside_dwe_cycles):
        assert float(fn(0.0, g)) == 0.0


def test_digital_closed_form():
    """Hand-computed digital cycles for a known geometry (n=16 channels)."""
    g = geom(cin=16, cout=32, k=3, hw=8)
    d = HW["diana"]["digital"]
    n = 16.0
    kdim = 16 * 9
    inner = -(-kdim // d["pe_cols"])  # ceil
    expected = (n / d["pe_rows"]) * inner * 64 / d["macs_per_cycle_per_pe"]
    expected += n * kdim / d["weight_load_bytes_per_cycle"]
    expected += d["setup_cycles"]
    expected *= n / (n + 0.5)  # gate
    got = float(C.diana_digital_cycles(n, g))
    np.testing.assert_allclose(got, expected, rtol=1e-6)


def test_dwe_beats_cluster_for_dw_work():
    g = geom(cin=64, cout=64, k=3, hw=16)
    dwe = float(C.darkside_dwe_cycles(64.0, g))
    clu = float(C.darkside_cluster_cycles(64.0, g))
    assert clu > 4 * dwe


def test_energy_positive_and_scales():
    g = geom()
    lats = [C.diana_layer_lats(16.0, 16.0, g)]
    p_act, p_idle, freq = C.diana_power()
    e1 = float(C.total_energy(lats, p_act, p_idle, freq))
    lats2 = [C.diana_layer_lats(32.0, 32.0, geom(cout=64))]
    e2 = float(C.total_energy(lats2, p_act, p_idle, freq))
    assert 0 < e1 < e2


def test_total_latency_sums_layers():
    g = geom()
    one = float(C.total_latency([C.diana_layer_lats(8.0, 8.0, g)]))
    two = float(C.total_latency([C.diana_layer_lats(8.0, 8.0, g)] * 2))
    np.testing.assert_allclose(two, 2 * one, rtol=1e-6)


def test_cost_gradient_flows_to_counts():
    g = geom()

    def cost(n_d):
        return C.total_latency([C.diana_layer_lats(n_d, g.cout - n_d, g)])

    grad = float(jax.grad(cost)(jnp.float32(16.0)))
    assert np.isfinite(grad) and grad != 0.0
