"""Shared NN building blocks for the ODiMO supernets (Layer-2, build-time).

Plain-JAX conv / batch-norm / linear primitives plus the straight-through
int8 weight quantizer used by every layer that executes on an int8 CU
(DIANA digital PE array, Darkside cluster/DWE). Parameters are nested dicts
(pytrees) so the AOT manifest can name every leaf.

Layout conventions: activations NHWC, conv weights HWIO, FC weights
``[in, out]``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.fake_quant import ste_int8_rows

BN_MOMENTUM = 0.9
BN_EPS = 1e-5


# ---------------------------------------------------------------------------
# Quantization (STE wrappers over the Pallas kernels)
# ---------------------------------------------------------------------------

def ste_int8(w: jnp.ndarray) -> jnp.ndarray:
    """Straight-through per-channel int8 fake-quantization.

    ``w`` is a conv (HWIO) or FC (``[in, out]``) weight; channels are the
    trailing (output) axis. Forward runs the Pallas kernel; gradient is the
    identity.
    """
    flat = w.reshape(-1, w.shape[-1]).T  # [Cout, F]
    return ste_int8_rows(flat).T.reshape(w.shape)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def conv_init(key, k: int, cin: int, cout: int) -> jnp.ndarray:
    """He-normal conv weight ``[k, k, cin, cout]``."""
    fan_in = k * k * cin
    std = jnp.sqrt(2.0 / fan_in)
    return std * jax.random.normal(key, (k, k, cin, cout), dtype=jnp.float32)


def dw_init(key, c: int) -> jnp.ndarray:
    """He-normal depthwise 3x3 weight ``[3, 3, c]``."""
    std = jnp.sqrt(2.0 / 9.0)
    return std * jax.random.normal(key, (3, 3, c), dtype=jnp.float32)


def fc_init(key, cin: int, cout: int) -> dict:
    std = jnp.sqrt(1.0 / cin)
    return {
        "w": std * jax.random.normal(key, (cin, cout), dtype=jnp.float32),
        "b": jnp.zeros((cout,), dtype=jnp.float32),
    }


def bn_init(c: int) -> dict:
    return {
        "scale": jnp.ones((c,), dtype=jnp.float32),
        "bias": jnp.zeros((c,), dtype=jnp.float32),
        "mean": jnp.zeros((c,), dtype=jnp.float32),
        "var": jnp.ones((c,), dtype=jnp.float32),
    }


# ---------------------------------------------------------------------------
# Forward primitives
# ---------------------------------------------------------------------------

def conv2d(x: jnp.ndarray, w: jnp.ndarray, stride: int = 1) -> jnp.ndarray:
    """'SAME' NHWC x HWIO convolution."""
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def dw_conv2d(x: jnp.ndarray, w: jnp.ndarray, stride: int = 1) -> jnp.ndarray:
    """'SAME' depthwise conv; ``w: [3, 3, C]``."""
    c = x.shape[-1]
    wio = w[:, :, None, :]  # [3,3,1,C] with feature_group_count=C
    return jax.lax.conv_general_dilated(
        x, wio, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"), feature_group_count=c)


def batch_norm(x: jnp.ndarray, p: dict, training: bool):
    """BatchNorm. Returns ``(y, new_stats)``; ``new_stats`` is ``p``'s
    ``mean``/``var`` updated with batch statistics when ``training``."""
    if training:
        axes = tuple(range(x.ndim - 1))
        mean = jnp.mean(x, axis=axes)
        var = jnp.var(x, axis=axes)
        new_mean = BN_MOMENTUM * p["mean"] + (1 - BN_MOMENTUM) * mean
        new_var = BN_MOMENTUM * p["var"] + (1 - BN_MOMENTUM) * var
    else:
        mean, var = p["mean"], p["var"]
        new_mean, new_var = p["mean"], p["var"]
    inv = jax.lax.rsqrt(var + BN_EPS) * p["scale"]
    y = (x - mean) * inv + p["bias"]
    return y, {"mean": new_mean, "var": new_var}


def global_avg_pool(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean(x, axis=(1, 2))
