"""Training-step machinery: losses, masked optimizers, metric plumbing.

One compiled ``train_step`` serves all three ODiMO phases (Sec. IV-A):

* **Warmup** — the Rust coordinator passes ``lam = 0`` and re-feeds the old
  ``theta`` / theta-optimizer state, so only W trains on the task loss;
* **Search** — ``lam > 0``; W and theta are trained jointly on
  ``L + lam * C`` (Eq. 1);
* **Final-Training** — the coordinator feeds the *discretized* one-hot
  theta and again discards theta updates.

Parameter roles are derived from the leaf path: ``theta`` leaves belong to
the mapping optimizer (always Adam, as in the paper), BN ``mean``/``var``
leaves are running statistics (updated by direct replacement, never by
gradient), everything else is a weight (SGD+momentum on DIANA, Adam on
Darkside — Sec. V-B).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.tree_util import tree_flatten_with_path, tree_unflatten

WEIGHT_DECAY = 1e-4
ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8
SGD_MOMENTUM = 0.9


def path_str(path) -> str:
    """Stable, human-readable leaf path: 'stem/w', 's0b1c1/theta', ..."""
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def leaf_role(path) -> str:
    s = path_str(path)
    leaf = s.split("/")[-1]
    if leaf == "theta":
        return "theta"
    if leaf in ("mean", "var"):
        return "bn_stat"
    return "weight"


# ---------------------------------------------------------------------------
# Losses / metrics
# ---------------------------------------------------------------------------

def cross_entropy(logits: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def accuracy(logits: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))


# ---------------------------------------------------------------------------
# Optimizers (masked, role-aware, over full param-shaped trees)
# ---------------------------------------------------------------------------

def zeros_like_tree(tree):
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


def opt_init(params):
    """Uniform optimizer state (used for both the W and theta optimizers):
    first/second moment trees shaped like ``params`` plus a step counter.
    SGD+momentum uses only ``m``."""
    return {"m": zeros_like_tree(params), "v": zeros_like_tree(params),
            "t": jnp.zeros((), dtype=jnp.float32)}


def apply_updates(params, grads, new_bn, opt_w, opt_th, lr_w, lr_th,
                  w_optimizer: str):
    """One optimizer step over every leaf, dispatched by role.

    ``new_bn`` maps layer name -> {'mean','var'} with the fresh running
    stats from the forward pass.
    """
    p_leaves, treedef = tree_flatten_with_path(params)
    g_leaves = [l for _, l in tree_flatten_with_path(grads)[0]]
    mw = [l for _, l in tree_flatten_with_path(opt_w["m"])[0]]
    vw = [l for _, l in tree_flatten_with_path(opt_w["v"])[0]]
    mt = [l for _, l in tree_flatten_with_path(opt_th["m"])[0]]
    vt = [l for _, l in tree_flatten_with_path(opt_th["v"])[0]]

    tw = opt_w["t"] + 1.0
    tt = opt_th["t"] + 1.0

    new_p, new_mw, new_vw, new_mt, new_vt = [], [], [], [], []
    for i, (path, p) in enumerate(p_leaves):
        role = leaf_role(path)
        g = g_leaves[i]
        if role == "bn_stat":
            # replace with the forward pass's running stats
            s = path_str(path).split("/")
            layer, stat = s[0], s[-1]
            new_p.append(new_bn[layer][stat])
            new_mw.append(mw[i]); new_vw.append(vw[i])
            new_mt.append(mt[i]); new_vt.append(vt[i])
        elif role == "theta":
            m = ADAM_B1 * mt[i] + (1 - ADAM_B1) * g
            v = ADAM_B2 * vt[i] + (1 - ADAM_B2) * g * g
            mhat = m / (1 - ADAM_B1 ** tt)
            vhat = v / (1 - ADAM_B2 ** tt)
            new_p.append(p - lr_th * mhat / (jnp.sqrt(vhat) + ADAM_EPS))
            new_mt.append(m); new_vt.append(v)
            new_mw.append(mw[i]); new_vw.append(vw[i])
        else:  # weight
            if w_optimizer == "sgdm":
                g = g + WEIGHT_DECAY * p
                m = SGD_MOMENTUM * mw[i] + g
                new_p.append(p - lr_w * m)
                new_mw.append(m); new_vw.append(vw[i])
            else:  # adam
                m = ADAM_B1 * mw[i] + (1 - ADAM_B1) * g
                v = ADAM_B2 * vw[i] + (1 - ADAM_B2) * g * g
                mhat = m / (1 - ADAM_B1 ** tw)
                vhat = v / (1 - ADAM_B2 ** tw)
                new_p.append(p - lr_w * mhat / (jnp.sqrt(vhat) + ADAM_EPS))
                new_mw.append(m); new_vw.append(v)
            new_mt.append(mt[i]); new_vt.append(vt[i])

    params2 = tree_unflatten(treedef, new_p)
    opt_w2 = {"m": tree_unflatten(treedef, new_mw),
              "v": tree_unflatten(treedef, new_vw), "t": tw}
    opt_th2 = {"m": tree_unflatten(treedef, new_mt),
               "v": tree_unflatten(treedef, new_vt), "t": tt}
    return params2, opt_w2, opt_th2
