"""Layer-1 Pallas kernels for the ODiMO reproduction.

Every kernel is lowered with ``interpret=True`` (the CPU PJRT client cannot
execute Mosaic custom-calls) and validated against the pure-jnp oracles in
:mod:`ref` by ``python/tests/``.
"""

from .fake_quant import fake_quant_int8, fake_quant_ternary
from .effective_weights import (
    effective_weights_fwd_kernel,
    effective_weights_ste,
)
from .matmul import matmul, matmul_kernel
from .dw_conv import dw_conv3x3

__all__ = [
    "fake_quant_int8",
    "fake_quant_ternary",
    "effective_weights_fwd_kernel",
    "effective_weights_ste",
    "matmul",
    "matmul_kernel",
    "dw_conv3x3",
]
