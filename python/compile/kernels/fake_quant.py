"""Pallas fake-quantization kernels (DIANA weight formats).

Layer-1 of the stack: these kernels implement the per-output-channel int8
and ternary fake-quantizers used by the DIANA mixed-precision supernet.
They are tiled along the output-channel axis: each grid step loads a
``[BC, F]`` block of the flattened weight tensor into VMEM, computes the
per-row scale (a row reduction) and writes the re-quantized block back.
On a real TPU this is one fused vector pass per block; here they are
lowered with ``interpret=True`` so the emitted HLO runs on the CPU PJRT
client (see DESIGN.md §Hardware-Adaptation).

Oracles: :mod:`ref` (``fake_quant_int8`` / ``fake_quant_ternary``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Default channel-block: chosen by the §Perf block sweep (EXPERIMENTS.md):
# 64 rows × up-to-6k f32 elements ≈ 1.1 MB of VMEM counting the fused
# kernel's input + three outputs — comfortably inside a 16 MB VMEM budget
# with double-buffering headroom, and within 20% of the flat part of the
# throughput curve (8→64 is a 2.4× speedup, 64→256 only +24%).
DEFAULT_BLOCK_C = 64


def _int8_kernel(w_ref, o_ref):
    w = w_ref[...]
    amax = jnp.max(jnp.abs(w), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / ref.INT8_LEVELS, 1.0)
    q = jnp.clip(jnp.round(w / scale), -ref.INT8_LEVELS, ref.INT8_LEVELS)
    o_ref[...] = q * scale


def _ternary_kernel(w_ref, o_ref):
    w = w_ref[...]
    amax = jnp.max(jnp.abs(w), axis=-1, keepdims=True)
    thr = ref.TERNARY_THR * amax
    mask = (jnp.abs(w) > thr).astype(w.dtype)
    denom = jnp.maximum(jnp.sum(mask, axis=-1, keepdims=True), 1.0)
    scale = jnp.sum(jnp.abs(w) * mask, axis=-1, keepdims=True) / denom
    o_ref[...] = jnp.sign(w) * mask * scale


def _row_blocked_call(kernel, w: jnp.ndarray, block_c: int) -> jnp.ndarray:
    """Run ``kernel`` over ``w: [C, F]`` in ``[block_c, F]`` row blocks.

    ``C`` is padded up to a multiple of ``block_c`` (padding rows are all
    zero, for which both quantizers are exact no-ops) and the output is
    sliced back.
    """
    c, f = w.shape
    bc = min(block_c, c)
    pad = (-c) % bc
    wp = jnp.pad(w, ((0, pad), (0, 0))) if pad else w
    out = pl.pallas_call(
        kernel,
        grid=((c + pad) // bc,),
        in_specs=[pl.BlockSpec((bc, f), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bc, f), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((c + pad, f), w.dtype),
        interpret=True,
    )(wp)
    return out[:c] if pad else out


@functools.partial(jax.jit, static_argnames=("block_c",))
def fake_quant_int8(w: jnp.ndarray, block_c: int = DEFAULT_BLOCK_C) -> jnp.ndarray:
    """Per-channel symmetric int8 fake-quantization of ``w: [C, F]``."""
    return _row_blocked_call(_int8_kernel, w, block_c)


@functools.partial(jax.jit, static_argnames=("block_c",))
def fake_quant_ternary(w: jnp.ndarray, block_c: int = DEFAULT_BLOCK_C) -> jnp.ndarray:
    """Per-channel ternary fake-quantization of ``w: [C, F]``."""
    return _row_blocked_call(_ternary_kernel, w, block_c)


# ---------------------------------------------------------------------------
# Straight-through wrappers (identity gradient). Needed because pallas_call
# has no AD rule: even under stop_gradient, linearization of the primal
# fails, so the whole quantizer is declared as a custom_vjp primitive.
# ---------------------------------------------------------------------------

@jax.custom_vjp
def ste_int8_rows(w: jnp.ndarray) -> jnp.ndarray:
    """STE per-row int8 fake-quant of ``[C, F]`` (Pallas forward)."""
    return fake_quant_int8(w)


ste_int8_rows.defvjp(lambda w: (fake_quant_int8(w), None),
                     lambda _, g: (g,))


@jax.custom_vjp
def ste_ternary_rows(w: jnp.ndarray) -> jnp.ndarray:
    """STE per-row ternary fake-quant of ``[C, F]`` (Pallas forward)."""
    return fake_quant_ternary(w)


ste_ternary_rows.defvjp(lambda w: (fake_quant_ternary(w), None),
                        lambda _, g: (g,))
