"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness ground truth: each Pallas kernel in
``fake_quant.py`` / ``effective_weights.py`` / ``matmul.py`` / ``dw_conv.py``
must match its oracle here to float32 tolerance (see python/tests/).

The quantizers implement the two weight formats of the DIANA SoC:

* ``fake_quant_int8`` — symmetric per-output-channel int8 (digital CU),
  scale = max|W_c| / 127, round-to-nearest, clip to [-127, 127].
* ``fake_quant_ternary`` — per-output-channel ternarization (analog AIMC
  CU): threshold t_c = TERNARY_THR * max|W_c|; weights with |w| <= t_c are
  zeroed, the rest snap to +/- s_c where s_c is the mean magnitude of the
  surviving weights (TWN-style scale).

All functions take weights in *channel-major flattened* layout
``[C_out, F]`` with ``F = C_in * K * K`` — the layout the kernels tile.
"""

from __future__ import annotations

import jax.numpy as jnp

INT8_LEVELS = 127.0
TERNARY_THR = 0.05


def fake_quant_int8(w: jnp.ndarray) -> jnp.ndarray:
    """Per-row symmetric int8 fake-quantization of ``w: [C, F]``."""
    amax = jnp.max(jnp.abs(w), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / INT8_LEVELS, 1.0)
    q = jnp.clip(jnp.round(w / scale), -INT8_LEVELS, INT8_LEVELS)
    return q * scale


def fake_quant_ternary(w: jnp.ndarray) -> jnp.ndarray:
    """Per-row ternary fake-quantization of ``w: [C, F]``."""
    amax = jnp.max(jnp.abs(w), axis=-1, keepdims=True)
    thr = TERNARY_THR * amax
    mask = (jnp.abs(w) > thr).astype(w.dtype)
    denom = jnp.maximum(jnp.sum(mask, axis=-1, keepdims=True), 1.0)
    scale = jnp.sum(jnp.abs(w) * mask, axis=-1, keepdims=True) / denom
    return jnp.sign(w) * mask * scale


def effective_weights(w: jnp.ndarray, theta: jnp.ndarray):
    """Eq. 5 effective weights for DIANA.

    ``w: [C, F]`` master weights, ``theta: [C, 2]`` per-channel softmaxed
    CU-assignment probabilities (column 0 = digital/int8, column 1 =
    analog/ternary). Returns ``(w_eff, q8, qt)``.
    """
    q8 = fake_quant_int8(w)
    qt = fake_quant_ternary(w)
    w_eff = theta[:, 0:1] * q8 + theta[:, 1:2] * qt
    return w_eff, q8, qt


def matmul(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """f32 matmul oracle, ``[M, K] @ [K, N]``."""
    return jnp.dot(x.astype(jnp.float32), y.astype(jnp.float32),
                   preferred_element_type=jnp.float32)


def dw_conv3x3(x: jnp.ndarray, k: jnp.ndarray, stride: int = 1) -> jnp.ndarray:
    """Depthwise 3x3 'SAME' conv oracle.

    ``x: [B, H, W, C]``, ``k: [3, 3, C]``. Returns ``[B, ceil(H/s),
    ceil(W/s), C]``.
    """
    b, h, w, c = x.shape
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    out = jnp.zeros((b, h, w, c), dtype=jnp.float32)
    for di in range(3):
        for dj in range(3):
            out = out + xp[:, di:di + h, dj:dj + w, :] * k[di, dj, :]
    if stride > 1:
        out = out[:, ::stride, ::stride, :]
    return out
