"""Fused effective-weights Pallas kernel — ODiMO Eq. 5, the training hot-spot.

For a DIANA-mapped layer, every training step must build

    W_eff[c] = theta[c, 0] * Q_int8(W[c]) + theta[c, 1] * Q_ternary(W[c])

for every output channel ``c``. Done naively (as in the paper's PyTorch
implementation) this is five separate elementwise passes over the weight
tensor per layer per step; this kernel fuses both per-channel quantizers and
the theta-mix into a single VMEM pass per ``[BC, F]`` block. The kernel also
emits the two quantized tensors ``q8``/``qt`` because the backward pass
needs them (see :func:`effective_weights_ste`).

Gradients: the kernel is wrapped in a ``custom_vjp`` implementing the
straight-through estimator used by the paper:

* ``dL/dW     = dL/dW_eff``            (STE: both quantizers pass gradients
  through unchanged, and ``theta`` rows are softmaxed so they sum to 1)
* ``dL/dtheta[c,0] = <dL/dW_eff[c], q8[c]>`` and analogously for column 1
  (exact gradient of the linear mix).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .fake_quant import DEFAULT_BLOCK_C


def _eff_kernel(w_ref, th_ref, weff_ref, q8_ref, qt_ref):
    w = w_ref[...]
    th = th_ref[...]
    # int8 branch
    amax = jnp.max(jnp.abs(w), axis=-1, keepdims=True)
    scale8 = jnp.where(amax > 0, amax / ref.INT8_LEVELS, 1.0)
    q8 = jnp.clip(jnp.round(w / scale8), -ref.INT8_LEVELS, ref.INT8_LEVELS) * scale8
    # ternary branch (reuses amax)
    thr = ref.TERNARY_THR * amax
    mask = (jnp.abs(w) > thr).astype(w.dtype)
    denom = jnp.maximum(jnp.sum(mask, axis=-1, keepdims=True), 1.0)
    scalet = jnp.sum(jnp.abs(w) * mask, axis=-1, keepdims=True) / denom
    qt = jnp.sign(w) * mask * scalet
    q8_ref[...] = q8
    qt_ref[...] = qt
    weff_ref[...] = th[:, 0:1] * q8 + th[:, 1:2] * qt


@functools.partial(jax.jit, static_argnames=("block_c",))
def effective_weights_fwd_kernel(w: jnp.ndarray, theta: jnp.ndarray,
                                 block_c: int = DEFAULT_BLOCK_C):
    """Forward-only fused kernel. ``w: [C, F]``, ``theta: [C, 2]``.

    Returns ``(w_eff, q8, qt)``, each ``[C, F]``.
    """
    c, f = w.shape
    bc = min(block_c, c)
    pad = (-c) % bc
    wp = jnp.pad(w, ((0, pad), (0, 0))) if pad else w
    thp = jnp.pad(theta, ((0, pad), (0, 0))) if pad else theta
    shapes = jax.ShapeDtypeStruct((c + pad, f), w.dtype)
    weff, q8, qt = pl.pallas_call(
        _eff_kernel,
        grid=((c + pad) // bc,),
        in_specs=[
            pl.BlockSpec((bc, f), lambda i: (i, 0)),
            pl.BlockSpec((bc, 2), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bc, f), lambda i: (i, 0)),
            pl.BlockSpec((bc, f), lambda i: (i, 0)),
            pl.BlockSpec((bc, f), lambda i: (i, 0)),
        ],
        out_shape=(shapes, shapes, shapes),
        interpret=True,
    )(wp, thp)
    if pad:
        weff, q8, qt = weff[:c], q8[:c], qt[:c]
    return weff, q8, qt


@jax.custom_vjp
def effective_weights_ste(w: jnp.ndarray, theta: jnp.ndarray) -> jnp.ndarray:
    """Differentiable Eq. 5 effective weights (STE), pallas-fused forward."""
    weff, _, _ = effective_weights_fwd_kernel(w, theta)
    return weff


def _ste_fwd(w, theta):
    weff, q8, qt = effective_weights_fwd_kernel(w, theta)
    return weff, (q8, qt)


def _ste_bwd(res, g):
    q8, qt = res
    # Straight-through for W: theta rows sum to 1 after softmax, so the
    # mix passes the gradient through unchanged.
    dw = g
    dth = jnp.stack(
        [jnp.sum(g * q8, axis=-1), jnp.sum(g * qt, axis=-1)], axis=-1)
    return dw, dth


effective_weights_ste.defvjp(_ste_fwd, _ste_bwd)
