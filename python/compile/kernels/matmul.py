"""MXU-tiled matmul Pallas kernel.

Classic three-level tiling: grid ``(M/bm, N/bn, K/bk)``, each step loads an
``[bm, bk]`` LHS block and a ``[bk, bn]`` RHS block into VMEM and
accumulates ``[bm, bn]`` partials directly in the (revisited) output block.
Block defaults are MXU-shaped (128x128 systolic array, f32 accumulation);
DESIGN.md §Hardware-Adaptation records the VMEM footprint / utilization
estimate. ``interpret=True`` lowers the grid to plain HLO for the CPU PJRT
runtime.

A ``custom_vjp`` expresses both backward matmuls with the same kernel so the
FC head of every supernet stays on the Pallas path during training.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _matmul_kernel(x_ref, y_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], y_ref[...],
                          preferred_element_type=jnp.float32)


def _pad_to(a: jnp.ndarray, m0: int, m1: int) -> jnp.ndarray:
    p0 = (-a.shape[0]) % m0
    p1 = (-a.shape[1]) % m1
    if p0 or p1:
        a = jnp.pad(a, ((0, p0), (0, p1)))
    return a


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul_kernel(x: jnp.ndarray, y: jnp.ndarray,
                  bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                  bk: int = DEFAULT_BK) -> jnp.ndarray:
    """``[M, K] @ [K, N] -> [M, N]`` in f32 via the tiled Pallas kernel."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch: {x.shape} @ {y.shape}"
    bm_, bn_, bk_ = min(bm, m), min(bn, n), min(bk, k)
    xp = _pad_to(x.astype(jnp.float32), bm_, bk_)
    yp = _pad_to(y.astype(jnp.float32), bk_, bn_)
    mp, kp = xp.shape
    _, np_ = yp.shape
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm_, np_ // bn_, kp // bk_),
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, yp)
    return out[:m, :n]


@jax.custom_vjp
def matmul(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Differentiable Pallas matmul (forward and both backwards tiled)."""
    return matmul_kernel(x, y)


def _mm_fwd(x, y):
    return matmul_kernel(x, y), (x, y)


def _mm_bwd(res, g):
    x, y = res
    return matmul_kernel(g, y.T), matmul_kernel(x.T, g)


matmul.defvjp(_mm_fwd, _mm_bwd)
