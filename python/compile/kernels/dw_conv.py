"""Depthwise 3x3 Pallas kernel — the Darkside DWE's operation.

The Darkside SoC accelerates exactly this op in its DepthWise Engine; the
kernel mirrors that dataflow: the grid walks ``(batch, channel-block)``,
each step holds one padded ``[H+2, W+2, BC]`` input slab and the 9
per-channel taps in VMEM and produces the ``[H, W, BC]`` output slab as nine
shifted multiply-accumulates (the DWE's line-buffer schedule, vectorized
over the channel lane dimension instead of the DWE's spatial shift
registers — see DESIGN.md §Hardware-Adaptation).

Stride-2 is handled by computing the stride-1 slab and subsampling in the
wrapper; edge SoC DW layers are small enough that the simplicity is worth
the 4x redundant MACs (the deployment cost models use the true DWE cycle
counts, not this kernel's).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_C = 16


def _dw_kernel(x_ref, k_ref, o_ref):
    # x_ref: [1, H+2, W+2, BC]; k_ref: [3, 3, BC]; o_ref: [1, H, W, BC]
    h = o_ref.shape[1]
    w = o_ref.shape[2]
    acc = jnp.zeros(o_ref.shape, dtype=jnp.float32)
    for di in range(3):
        for dj in range(3):
            acc = acc + x_ref[:, di:di + h, dj:dj + w, :] * k_ref[di, dj, :]
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("stride", "block_c"))
def dw_conv3x3(x: jnp.ndarray, k: jnp.ndarray, stride: int = 1,
               block_c: int = DEFAULT_BLOCK_C) -> jnp.ndarray:
    """Depthwise 3x3 'SAME' convolution via the Pallas kernel.

    ``x: [B, H, W, C]``, ``k: [3, 3, C]`` -> ``[B, ceil(H/s), ceil(W/s), C]``.
    """
    b, h, w, c = x.shape
    bc = min(block_c, c)
    pad_c = (-c) % bc
    xp = jnp.pad(x.astype(jnp.float32),
                 ((0, 0), (1, 1), (1, 1), (0, pad_c)))
    kp = jnp.pad(k.astype(jnp.float32), ((0, 0), (0, 0), (0, pad_c)))
    cp = c + pad_c
    out = pl.pallas_call(
        _dw_kernel,
        grid=(b, cp // bc),
        in_specs=[
            pl.BlockSpec((1, h + 2, w + 2, bc), lambda i, j: (i, 0, 0, j)),
            pl.BlockSpec((3, 3, bc), lambda i, j: (0, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, h, w, bc), lambda i, j: (i, 0, 0, j)),
        out_shape=jax.ShapeDtypeStruct((b, h, w, cp), jnp.float32),
        interpret=True,
    )(xp, kp)
    out = out[..., :c]
    if stride > 1:
        out = out[:, ::stride, ::stride, :]
    return out
