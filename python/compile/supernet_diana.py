"""DIANA mixed-precision supernet (ODiMO Sec. IV-B).

CIFAR-style ResNets where every convolution's output channels are softly
assigned between the two DIANA CUs — the int8 digital 16x16 PE grid and the
ternary analog AIMC array — through per-channel ``theta`` parameters. The
forward pass builds Eq. 5 *effective weights* with the fused Pallas kernel
(:func:`..kernels.effective_weights_ste`), so selecting a precision is the
same act as selecting a CU.

Also hosts the ``prune`` mode used by the Fig. 7-top baseline: the same
per-channel gating machinery, but the second "CU" is channel removal
(PIT-style structured pruning with everything kept on the digital CU).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from . import layers as L
from .costs import LayerGeom, diana_layer_lats, diana_digital_cycles
from .kernels import effective_weights_ste, fake_quant_int8, matmul


@dataclass(frozen=True)
class DianaConfig:
    name: str
    input_hw: int = 32
    stem_width: int = 8
    stage_widths: tuple = (8, 16, 32)
    blocks_per_stage: int = 3
    num_classes: int = 10
    # 'map'    — digital vs analog per channel (ODiMO, Sec. IV-B)
    # 'prune'  — keep vs prune per channel (Fig. 7-top baseline)
    # 'fixed8' — plain int8 net, everything digital (Table II baseline)
    mode: str = "map"


# ---------------------------------------------------------------------------
# Geometry
# ---------------------------------------------------------------------------

def build_geoms(cfg: DianaConfig):
    """Static per-layer geometry, in parameter order. Returns
    ``(geoms, fc_geom)`` where each searchable conv has one entry."""
    geoms = []
    hw = cfg.input_hw
    geoms.append(LayerGeom("stem", "conv", 3, cfg.stem_width, 3, hw, hw,
                           1, True))
    cin = cfg.stem_width
    for si, cw in enumerate(cfg.stage_widths):
        for bi in range(cfg.blocks_per_stage):
            stride = 2 if (si > 0 and bi == 0) else 1
            hw_out = math.ceil(hw / stride)
            geoms.append(LayerGeom(f"s{si}b{bi}c1", "conv", cin, cw, 3,
                                   hw_out, hw_out, stride, True))
            geoms.append(LayerGeom(f"s{si}b{bi}c2", "conv", cw, cw, 3,
                                   hw_out, hw_out, 1, True))
            if stride != 1 or cin != cw:
                geoms.append(LayerGeom(f"s{si}b{bi}dn", "pw", cin, cw, 1,
                                       hw_out, hw_out, stride, True))
            hw = hw_out
            cin = cw
    fc_geom = LayerGeom("fc", "fc", cin, cfg.num_classes, 1, 1, 1, 1, False)
    return geoms, fc_geom


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init(key, cfg: DianaConfig) -> dict:
    geoms, fc_geom = build_geoms(cfg)
    params = {}
    keys = jax.random.split(key, len(geoms) + 1)
    for g, k in zip(geoms, keys[:-1]):
        layer = {
            "w": L.conv_init(k, g.k, g.cin, g.cout),
            "bn": L.bn_init(g.cout),
        }
        if cfg.mode != "fixed8":
            layer["theta"] = jnp.zeros((g.cout, 2), dtype=jnp.float32)
        params[g.name] = layer
    params["fc"] = L.fc_init(keys[-1], fc_geom.cin, fc_geom.cout)
    return params


def theta_paths(cfg: DianaConfig):
    """Names of the searchable layers, in the order ``apply`` reports
    their latencies (used by the AOT manifest)."""
    geoms, _ = build_geoms(cfg)
    return [g.name for g in geoms]


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _diana_conv(x, p, g: LayerGeom, cfg: DianaConfig, training: bool):
    """One ODiMO-mapped convolution: Eq. 5 effective weights + BN stats +
    per-CU latency terms."""
    w = p["w"]
    flat = w.transpose(3, 0, 1, 2).reshape(g.cout, -1)
    if cfg.mode == "fixed8":
        from .kernels.fake_quant import ste_int8_rows
        weff_flat = ste_int8_rows(flat)
        lats = [diana_digital_cycles(float(g.cout), g)]
        counts = (jnp.float32(g.cout), jnp.float32(0.0))
        weff = weff_flat.reshape(g.cout, g.k, g.k, g.cin).transpose(1, 2, 3, 0)
        y = L.conv2d(x, weff, g.stride)
        y, new_stats = L.batch_norm(y, p["bn"], training)
        return y, new_stats, lats, counts
    th = jax.nn.softmax(p["theta"], axis=-1)
    if cfg.mode == "prune":
        # keep-vs-prune: int8 branch scaled by keep-probability, no analog.
        from .kernels.fake_quant import ste_int8_rows
        weff_flat = th[:, 0:1] * ste_int8_rows(flat)
        n_keep = jnp.sum(th[:, 0])
        lats = [diana_digital_cycles(n_keep, g)]
        counts = (n_keep, jnp.float32(0.0))
    else:
        weff_flat = effective_weights_ste(flat, th)
        n_d = jnp.sum(th[:, 0])
        n_a = jnp.sum(th[:, 1])
        lats = diana_layer_lats(n_d, n_a, g)
        counts = (n_d, n_a)
    weff = weff_flat.reshape(g.cout, g.k, g.k, g.cin).transpose(1, 2, 3, 0)
    y = L.conv2d(x, weff, g.stride)
    y, new_stats = L.batch_norm(y, p["bn"], training)
    return y, new_stats, lats, counts


def apply(params, x, cfg: DianaConfig, training: bool):
    """Supernet forward.

    Returns ``(logits, new_bn_stats, per_layer, fc_lat)`` where
    ``per_layer`` is a list of ``(name, lats, (n_cu0, n_cu1))`` in geometry
    order and ``fc_lat`` the fixed digital-CU cycles of the FC head.
    """
    geoms, fc_geom = build_geoms(cfg)
    by_name = {g.name: g for g in geoms}
    new_bn = {}
    per_layer = []

    def run(name, x, act=True):
        g = by_name[name]
        y, stats, lats, counts = _diana_conv(x, params[name], g, cfg, training)
        new_bn[name] = stats
        per_layer.append((name, lats, counts))
        return jax.nn.relu(y) if act else y

    h = run("stem", x)
    cin = cfg.stem_width
    for si, cw in enumerate(cfg.stage_widths):
        for bi in range(cfg.blocks_per_stage):
            stride = 2 if (si > 0 and bi == 0) else 1
            ident = h
            h1 = run(f"s{si}b{bi}c1", h)
            h2 = run(f"s{si}b{bi}c2", h1, act=False)
            if stride != 1 or cin != cw:
                ident = run(f"s{si}b{bi}dn", ident, act=False)
            h = jax.nn.relu(h2 + ident)
            cin = cw

    feat = L.global_avg_pool(h)
    wq = L.ste_int8(params["fc"]["w"])
    logits = matmul(feat, wq) + params["fc"]["b"]
    fc_lat = diana_digital_cycles(float(fc_geom.cout), fc_geom)
    return logits, new_bn, per_layer, fc_lat
