"""Differentiable hardware cost models (ODiMO Eq. 3 / Eq. 4).

Smooth, theta-differentiable cycle/energy models for the DIANA and Darkside
CUs. Coefficients come from ``hw/constants.json`` — the same file the Rust
analytical model (``rust/src/soc/analytical.rs``) and detailed simulator
read, so the training-time model and the deployment-time evaluation stay
coefficient-for-coefficient in sync (cross-checked by tests on both sides).

Differentiable relaxations used here (vs the Rust analytical model):

* integer ``ceil(n/d)`` over the *searched* channel count ``n`` becomes the
  linear ``n/d`` (ceils over static geometry stay exact);
* the per-CU fixed setup cost is gated by ``gate(n) = n / (n + 0.5)`` so a
  CU assigned ~0 channels contributes ~0 cycles and the gradient can turn a
  CU completely off;
* the layer-latency ``max()`` across CUs (Eq. 3) becomes a softmax-weighted
  sum (the paper's own smooth substitution).
"""

from __future__ import annotations

import json
import math
from dataclasses import dataclass
from pathlib import Path

import jax
import jax.numpy as jnp

_HW_PATH = Path(__file__).resolve().parents[2] / "hw" / "constants.json"
HW = json.loads(_HW_PATH.read_text())

SMOOTHMAX_TEMP = 0.05  # relative temperature for the Eq. 3 smooth max


@dataclass(frozen=True)
class LayerGeom:
    """Static geometry of one mappable layer."""
    name: str
    ltype: str          # 'conv' | 'dw' | 'pw' | 'fc'
    cin: int
    cout: int
    k: int              # spatial kernel size (1 for pw/fc)
    ox: int             # output width  (1 for fc)
    oy: int             # output height (1 for fc)
    stride: int = 1
    searchable: bool = False

    @property
    def macs_per_out_channel(self) -> int:
        if self.ltype == "dw":
            return self.k * self.k * self.ox * self.oy
        return self.cin * self.k * self.k * self.ox * self.oy


def gate(n):
    """Soft 'is this CU used at all' indicator, ~1 for n >= 1, 0 at n = 0."""
    return n / (n + 0.5)


def smoothmax(lats):
    """Differentiable max over a list of scalar latencies (Eq. 3)."""
    v = jnp.stack(lats)
    t = SMOOTHMAX_TEMP * (jnp.sum(v) + 1e-6)
    w = jax.nn.softmax(v / t)
    return jnp.sum(w * v)


# ---------------------------------------------------------------------------
# DIANA (Sec. IV-B: digital int8 PE grid + ternary analog AIMC)
# ---------------------------------------------------------------------------

def diana_digital_cycles(n, g: LayerGeom):
    """Digital 16x16 PE-grid cycles for ``n`` (possibly fractional expected)
    output channels of layer ``g``; int8 weights."""
    d = HW["diana"]["digital"]
    rows = d["pe_rows"]
    # static inner tiling over the input-patch dimension is exact
    kdim = g.cin * g.k * g.k if g.ltype != "dw" else g.k * g.k
    inner = math.ceil(kdim / d["pe_cols"])
    compute = (n / rows) * inner * g.ox * g.oy / d["macs_per_cycle_per_pe"]
    if g.ltype == "dw":
        compute = compute * HW["diana"]["dw_digital_inefficiency"]
    wload = n * kdim / d["weight_load_bytes_per_cycle"]
    return gate(n) * (compute + wload + d["setup_cycles"])


def diana_analog_cycles(n, g: LayerGeom):
    """Analog AIMC cycles: dominated by ternary weight (re)loading plus one
    array operation per output pixel per column-tile."""
    a = HW["diana"]["analog"]
    kdim = g.cin * g.k * g.k if g.ltype != "dw" else g.k * g.k
    row_tiles = math.ceil(kdim / a["array_rows"])  # static
    col_tiles = n / a["array_cols"]                # smooth
    cells = n * kdim
    load = cells / a["cells_load_per_cycle"]
    compute = row_tiles * (col_tiles + gate(n) * 0.5) * g.ox * g.oy \
        * a["cycles_per_analog_op"]
    return gate(n) * (load + compute + a["setup_cycles"])


def diana_layer_lats(n_d, n_a, g: LayerGeom):
    """Per-CU latency vector ``[digital, analog]`` for one layer."""
    return [diana_digital_cycles(n_d, g), diana_analog_cycles(n_a, g)]


# ---------------------------------------------------------------------------
# Darkside (Sec. IV-C: 8-core RISC-V cluster + DepthWise Engine)
# ---------------------------------------------------------------------------

def darkside_cluster_cycles(n, g: LayerGeom, as_dw: bool = False):
    """Cluster cycles for ``n`` output channels executed as a standard (or,
    for baselines, depthwise) convolution."""
    c = HW["darkside"]["cluster"]
    if as_dw or g.ltype == "dw":
        macs = n * g.k * g.k * g.ox * g.oy
        eff = c["macs_per_cycle_dw"]
        ovh = 1.0
    else:
        macs = n * g.cin * g.k * g.k * g.ox * g.oy
        eff = c["macs_per_cycle_std"]
        ovh = c["im2col_overhead"]
    return gate(n) * (macs * ovh / eff + c["setup_cycles"])


def darkside_dwe_cycles(n, g: LayerGeom):
    """DepthWise Engine cycles for ``n`` depthwise output channels."""
    d = HW["darkside"]["dwe"]
    macs = n * g.k * g.k * g.ox * g.oy
    cfg = n * g.k * g.k / d["weight_cfg_cells_per_cycle"]
    return gate(n) * (macs / d["macs_per_cycle"] + cfg + d["setup_cycles"])


def darkside_layer_lats(n_conv, n_dw, g: LayerGeom):
    """Per-CU latency vector ``[cluster(std conv), DWE(dw)]``."""
    return [darkside_cluster_cycles(n_conv, g), darkside_dwe_cycles(n_dw, g)]


# ---------------------------------------------------------------------------
# Aggregation: Eq. 3 (latency) and Eq. 4 (energy)
# ---------------------------------------------------------------------------

def total_latency(per_layer_lats):
    """Eq. 3: sum over layers of the (smooth) max across CUs. Cycles."""
    return sum(smoothmax(lats) if len(lats) > 1 else lats[0]
               for lats in per_layer_lats)


def total_energy(per_layer_lats, p_act_mw, p_idle_mw, freq_mhz):
    """Eq. 4: active energy per CU + idle-floor energy over the layer
    latency, accumulated across layers. Returns microjoules.

    ``per_layer_lats[l][i]`` must be ordered like ``p_act_mw[i]``.
    """
    us_per_cycle = 1.0 / freq_mhz
    e = 0.0
    for lats in per_layer_lats:
        m = smoothmax(lats) if len(lats) > 1 else lats[0]
        active = sum(p * lat for p, lat in zip(p_act_mw, lats))
        e = e + (active + p_idle_mw * m) * us_per_cycle  # mW * us = nJ
    return e * 1e-3  # uJ


def diana_power():
    return ([HW["diana"]["digital"]["p_act_mw"],
             HW["diana"]["analog"]["p_act_mw"]],
            HW["diana"]["p_idle_mw"], HW["diana"]["freq_mhz"])


def darkside_power():
    return ([HW["darkside"]["cluster"]["p_act_mw"],
             HW["darkside"]["dwe"]["p_act_mw"]],
            HW["darkside"]["p_idle_mw"], HW["darkside"]["freq_mhz"])
