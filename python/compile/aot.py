"""AOT lowering: JAX -> HLO text + JSON manifest, per model variant.

This is the only place Python touches the artifact boundary. For every
variant in :mod:`variants` it lowers four entry points (``init``, ``train``,
``eval``, ``cost``) to **HLO text** and writes a manifest describing every
input/output tensor so the Rust runtime can bind buffers by name and shape.

HLO *text* — not ``.serialize()`` — is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the published ``xla`` 0.1.6 crate) rejects; the text
parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Usage::

    python -m compile.aot --out-dir ../artifacts [--variant NAME ...]
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc
from jax.tree_util import tree_flatten_with_path

from . import variants as V
from .train import path_str

METRICS_TRAIN = ["loss", "ce", "acc", "cost_lat_cycles", "cost_energy_uj"]
METRICS_EVAL = ["correct", "loss_sum"]

_DTYPE_NAMES = {"float32": "f32", "int32": "i32", "uint32": "u32"}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _leaf_specs(prefix: str, tree):
    """Flatten a pytree into ordered (name, shape, dtype) io specs."""
    specs = []
    for path, leaf in tree_flatten_with_path(tree)[0]:
        name = path_str(path)
        name = f"{prefix}/{name}" if name else prefix
        dt = _DTYPE_NAMES.get(str(leaf.dtype), str(leaf.dtype))
        specs.append({"name": name, "shape": list(leaf.shape), "dtype": dt})
    return specs


def _io_spec(arg_names, example_args, out_tree):
    inputs = []
    for name, arg in zip(arg_names, example_args):
        inputs.extend(_leaf_specs(name, arg))
    outputs = []
    for name, out in out_tree:
        outputs.extend(_leaf_specs(name, out))
    return inputs, outputs


def lower_variant(name: str, out_dir: Path, verbose: bool = True) -> dict:
    var = V.REGISTRY[name]
    init_fn, train_fn, eval_fn, cost_fn = V.build_fns(var)
    ds = var.dataset

    t0 = time.time()
    seed0 = jnp.int32(0)
    params, opt_w, opt_th = jax.eval_shape(init_fn, seed0)
    # concrete init for cost-scale evaluation
    cparams, _, _ = init_fn(0)
    mat0, totals0 = cost_fn(cparams)
    cost_scale = {"latency_cycles": float(totals0[0]),
                  "energy_uj": float(totals0[1])}

    x = jnp.zeros((ds.batch, ds.hw, ds.hw, 3), jnp.float32)
    y = jnp.zeros((ds.batch,), jnp.int32)
    scalars = [jnp.float32(0) for _ in range(4)]  # lam, cost_sel, lr_w, lr_th

    functions = {}

    def emit(fn_name, fn, example_args, arg_names, out_named):
        # keep_unused=True: the manifest promises every input, even ones a
        # function ignores (e.g. `cost` reads only the θ leaves) — without
        # it XLA DCEs parameters and the Rust buffer binding goes stale.
        lowered = jax.jit(fn, keep_unused=True).lower(*example_args)
        text = to_hlo_text(lowered)
        fname = f"{name}_{fn_name}.hlo.txt"
        (out_dir / fname).write_text(text)
        inputs, outputs = _io_spec(arg_names, example_args, out_named)
        functions[fn_name] = {"file": fname, "inputs": inputs,
                              "outputs": outputs}
        if verbose:
            print(f"  {fn_name}: {len(text) / 1e6:.2f} MB, "
                  f"{len(inputs)} in / {len(outputs)} out", flush=True)

    state_shapes = jax.eval_shape(
        lambda p, ow, ot: (p, ow, ot), params, opt_w, opt_th)

    emit("init", init_fn, (seed0,), ["seed"],
         [("params", state_shapes[0]), ("opt_w", state_shapes[1]),
          ("opt_th", state_shapes[2])])

    train_out = jax.eval_shape(
        train_fn, params, opt_w, opt_th, x, y, *scalars)
    emit("train", train_fn,
         (params, opt_w, opt_th, x, y, *scalars),
         ["params", "opt_w", "opt_th", "x", "y", "lam", "cost_sel",
          "lr_w", "lr_th"],
         [("params", train_out[0]), ("opt_w", train_out[1]),
          ("opt_th", train_out[2]), ("metrics", train_out[3])])

    eval_out = jax.eval_shape(eval_fn, params, x, y)
    emit("eval", eval_fn, (params, x, y), ["params", "x", "y"],
         [("metrics", eval_out)])

    cost_out = jax.eval_shape(cost_fn, params)
    emit("cost", cost_fn, (params,), ["params"],
         [("layer_mat", cost_out[0]), ("totals", cost_out[1])])

    manifest = {
        "variant": name,
        "platform": var.platform,
        "w_optimizer": var.w_optimizer,
        "search_kind": var.search_kind,
        "dataset": {"name": ds.name, "hw": ds.hw, "classes": ds.classes,
                    "batch": ds.batch},
        "layers": V.layer_table(var),
        "cost_scale": cost_scale,
        "metrics_train": METRICS_TRAIN,
        "metrics_eval": METRICS_EVAL,
        "functions": functions,
    }
    (out_dir / f"{name}.manifest.json").write_text(
        json.dumps(manifest, indent=1))
    if verbose:
        print(f"  manifest + 4 HLO files in {time.time() - t0:.1f}s",
              flush=True)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--variant", action="append", default=None,
                    help="variant name (repeatable); default: all")
    args = ap.parse_args()
    out_dir = Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    names = args.variant or list(V.REGISTRY)
    for n in names:
        print(f"[aot] lowering {n}", flush=True)
        lower_variant(n, out_dir)
    print(f"[aot] done: {len(names)} variants -> {out_dir}", flush=True)


if __name__ == "__main__":
    main()
