"""Variant registry: every (network, dataset, SoC) combination the paper's
evaluation needs, with its four AOT entry points.

Each variant provides:

* ``init_fn(seed)``                       -> (params, opt_w, opt_th)
* ``train_fn(params, opt_w, opt_th, x, y, lam, cost_sel, lr_w, lr_th)``
      -> (params', opt_w', opt_th', metrics[5])
  metrics = [loss, ce, acc, cost_lat_cycles, cost_energy_uj];
  ``cost_sel`` selects the optimization target at runtime
  (0 = latency Eq. 3, 1 = energy Eq. 4) so one artifact serves Fig. 5/6.
* ``eval_fn(params, x, y)``               -> metrics[2] = [correct, loss_sum]
  (inference-mode BN, current theta)
* ``cost_fn(params)``                     -> (layer_mat [L,4], totals[2])
  layer_mat rows = [n_cu0, n_cu1, lat_cu0, lat_cu1] in layer order;
  totals = [latency_cycles, energy_uJ].

The variant table mirrors DESIGN.md §4. Model depths/widths are scaled to
the CPU training budget (documented substitution); the *structure* of each
search space matches the paper exactly.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp

from . import costs as C
from . import supernet_darkside as DS
from . import supernet_diana as DI
from . import train as T


@dataclass(frozen=True)
class DatasetSpec:
    name: str
    hw: int
    classes: int
    batch: int


SYNTH_C10 = DatasetSpec("synth-cifar10", 32, 10, 64)
SYNTH_C100 = DatasetSpec("synth-cifar100", 32, 100, 64)
SYNTH_IMGNET = DatasetSpec("synth-imagenet", 64, 100, 32)


@dataclass
class Variant:
    name: str
    platform: str            # 'diana' | 'darkside'
    dataset: DatasetSpec
    w_optimizer: str         # 'sgdm' | 'adam'
    cfg: object = None
    search_kind: str = "channel"  # 'channel' | 'split' | 'layerwise' | 'prune'


def _registry() -> dict:
    v = {}
    v["diana_resnet20_c10"] = Variant(
        "diana_resnet20_c10", "diana", SYNTH_C10, "sgdm",
        DI.DianaConfig("diana_resnet20_c10", 32, 8, (8, 16, 32), 3, 10))
    v["diana_resnet8_c100"] = Variant(
        "diana_resnet8_c100", "diana", SYNTH_C100, "sgdm",
        DI.DianaConfig("diana_resnet8_c100", 32, 16, (16, 32, 64), 1, 100))
    v["diana_resnet8_imgnet"] = Variant(
        "diana_resnet8_imgnet", "diana", SYNTH_IMGNET, "sgdm",
        DI.DianaConfig("diana_resnet8_imgnet", 64, 16, (16, 32, 64), 1, 100))
    v["diana_resnet20_c10_prune"] = Variant(
        "diana_resnet20_c10_prune", "diana", SYNTH_C10, "sgdm",
        DI.DianaConfig("diana_resnet20_c10_prune", 32, 8, (8, 16, 32), 3, 10,
                       mode="prune"),
        search_kind="prune")

    def ds_cfg(name, ds, classes, wm=1.0, mode="dw_vs_conv"):
        return DS.DarksideConfig(name, ds.hw, 8,
                                 ((8, 1, 16), (16, 2, 32), (32, 1, 32),
                                  (32, 2, 64), (64, 1, 64), (64, 2, 128),
                                  (128, 1, 128)),
                                 classes, wm, mode)

    v["darkside_mbv1_c10"] = Variant(
        "darkside_mbv1_c10", "darkside", SYNTH_C10, "adam",
        ds_cfg("darkside_mbv1_c10", SYNTH_C10, 10), search_kind="split")
    v["darkside_mbv1_c10_w050"] = Variant(
        "darkside_mbv1_c10_w050", "darkside", SYNTH_C10, "adam",
        ds_cfg("darkside_mbv1_c10_w050", SYNTH_C10, 10, wm=0.5),
        search_kind="split")
    v["darkside_mbv1_c10_w025"] = Variant(
        "darkside_mbv1_c10_w025", "darkside", SYNTH_C10, "adam",
        ds_cfg("darkside_mbv1_c10_w025", SYNTH_C10, 10, wm=0.25),
        search_kind="split")
    v["darkside_mbv1_c100"] = Variant(
        "darkside_mbv1_c100", "darkside", SYNTH_C100, "adam",
        ds_cfg("darkside_mbv1_c100", SYNTH_C100, 100), search_kind="split")
    v["darkside_mbv1_imgnet"] = Variant(
        "darkside_mbv1_imgnet", "darkside", SYNTH_IMGNET, "adam",
        ds_cfg("darkside_mbv1_imgnet", SYNTH_IMGNET, 100,
               mode="dw_vs_dwsep"), search_kind="split")
    v["darkside_mbv1_c10_layerwise"] = Variant(
        "darkside_mbv1_c10_layerwise", "darkside", SYNTH_C10, "adam",
        ds_cfg("darkside_mbv1_c10_layerwise", SYNTH_C10, 10,
               mode="layerwise"), search_kind="layerwise")

    # plain (non-supernet) baselines, used to measure the Table II search
    # overhead: the "most demanding baseline" of each platform
    for name, base in [("diana_resnet20_c10", "c10"),
                       ("diana_resnet8_c100", "c100"),
                       ("diana_resnet8_imgnet", "imgnet")]:
        src = v[name]
        fixed_cfg = DI.DianaConfig(
            name + "_fixed", src.cfg.input_hw, src.cfg.stem_width,
            src.cfg.stage_widths, src.cfg.blocks_per_stage,
            src.cfg.num_classes, mode="fixed8")
        v[name + "_fixed"] = Variant(name + "_fixed", "diana", src.dataset,
                                     "sgdm", fixed_cfg, search_kind="fixed")
    for name in ["darkside_mbv1_c10", "darkside_mbv1_c100",
                 "darkside_mbv1_imgnet"]:
        src = v[name]
        fixed_cfg = DS.DarksideConfig(
            name + "_fixed", src.cfg.input_hw, src.cfg.stem_width,
            src.cfg.blocks, src.cfg.num_classes, src.cfg.width_mult,
            "fixed_conv")
        v[name + "_fixed"] = Variant(name + "_fixed", "darkside",
                                     src.dataset, "adam", fixed_cfg,
                                     search_kind="fixed")
    return v


REGISTRY = _registry()


# ---------------------------------------------------------------------------
# Per-platform adapters
# ---------------------------------------------------------------------------

def _diana_forward(var: Variant, params, x, training: bool):
    logits, new_bn, per_layer, fc_lat = DI.apply(params, x, var.cfg, training)
    lat_vectors = []
    records = []
    for (_, lats, counts) in per_layer:
        lv = lats if len(lats) == 2 else [lats[0], jnp.float32(0.0)]
        lat_vectors.append((lv, "max"))
        records.append([counts[0], counts[1], lv[0], lv[1]])
    lat_vectors.append(([fc_lat, jnp.float32(0.0)], "max"))
    records.append([jnp.float32(var.cfg.num_classes), jnp.float32(0.0),
                    fc_lat, jnp.float32(0.0)])
    return logits, new_bn, lat_vectors, records


def _darkside_forward(var: Variant, params, x, training: bool):
    logits, new_bn, per_layer = DS.apply(params, x, var.cfg, training)
    lat_vectors = []
    records = []
    for (name, lats, combine, n_cl) in per_layer:
        lat_vectors.append((lats, combine))
        # lats are [cluster, dwe]; n_dwe = total channels - n_cluster when
        # the layer is searchable (else 0)
        geom_c = lats  # placeholder for shape; counts recorded explicitly
        records.append([n_cl, jnp.float32(0.0), lats[0], lats[1]])
    return logits, new_bn, lat_vectors, records


def _forward(var: Variant, params, x, training: bool):
    if var.platform == "diana":
        return _diana_forward(var, params, x, training)
    return _darkside_forward(var, params, x, training)


def _totals(var: Variant, lat_vectors):
    lat = jnp.float32(0.0)
    per_layer_maxes = []
    for lats, combine in lat_vectors:
        m = C.smoothmax(lats) if combine == "max" else lats[0] + lats[1]
        lat = lat + m
        per_layer_maxes.append((lats, m))
    p_act, p_idle, freq = (C.diana_power() if var.platform == "diana"
                           else C.darkside_power())
    us_per_cycle = 1.0 / freq  # cycles / MHz = microseconds
    en = jnp.float32(0.0)
    for lats, m in per_layer_maxes:
        active = sum(p * l for p, l in zip(p_act, lats))
        # mW * us = nJ
        en = en + (active + p_idle * m) * us_per_cycle
    return lat, en * 1e-3  # nJ -> uJ


# ---------------------------------------------------------------------------
# Entry-point builders
# ---------------------------------------------------------------------------

def build_fns(var: Variant):
    """Build (init_fn, train_fn, eval_fn, cost_fn) for a variant."""
    plat_init = DI.init if var.platform == "diana" else DS.init

    def init_fn(seed):
        key = jax.random.PRNGKey(seed)
        params = plat_init(key, var.cfg)
        return params, T.opt_init(params), T.opt_init(params)

    def loss_and_metrics(params, x, y, lam, cost_sel, training=True):
        logits, new_bn, lat_vectors, records = _forward(
            var, params, x, training)
        ce = T.cross_entropy(logits, y)
        lat, en = _totals(var, lat_vectors)
        cost = (1.0 - cost_sel) * lat + cost_sel * en
        loss = ce + lam * cost
        acc = T.accuracy(logits, y)
        return loss, (new_bn, ce, acc, lat, en, records)

    def train_fn(params, opt_w, opt_th, x, y, lam, cost_sel, lr_w, lr_th):
        (loss, aux), grads = jax.value_and_grad(
            lambda p: loss_and_metrics(p, x, y, lam, cost_sel),
            has_aux=True)(params)
        new_bn, ce, acc, lat, en, _ = aux
        params2, opt_w2, opt_th2 = T.apply_updates(
            params, grads, new_bn, opt_w, opt_th, lr_w, lr_th,
            var.w_optimizer)
        metrics = jnp.stack([loss, ce, acc, lat, en])
        return params2, opt_w2, opt_th2, metrics

    def eval_fn(params, x, y):
        logits, _, _, _ = _forward(var, params, x, training=False)
        correct = jnp.sum(
            (jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
        loss_sum = T.cross_entropy(logits, y) * x.shape[0]
        return jnp.stack([correct, loss_sum])

    def cost_fn(params):
        x = jnp.zeros((1, var.dataset.hw, var.dataset.hw, 3), jnp.float32)
        _, _, lat_vectors, records = _forward(var, params, x, training=False)
        lat, en = _totals(var, lat_vectors)
        mat = jnp.stack([jnp.stack(r) for r in records])
        return mat, jnp.stack([lat, en])

    return init_fn, train_fn, eval_fn, cost_fn


def layer_table(var: Variant):
    """Static layer metadata for the manifest (geometry + search info),
    in the same order cost_fn emits rows."""
    rows = []
    fixed = var.search_kind == "fixed"
    if var.platform == "diana":
        geoms, fc_geom = DI.build_geoms(var.cfg)
        for g in geoms:
            rows.append(dict(name=g.name, ltype=g.ltype, cin=g.cin,
                             cout=g.cout, k=g.k, ox=g.ox, oy=g.oy,
                             stride=g.stride,
                             searchable=g.searchable and not fixed,
                             theta_len=0 if fixed else 2 * g.cout))
        rows.append(dict(name="fc", ltype="fc", cin=fc_geom.cin,
                         cout=fc_geom.cout, k=1, ox=1, oy=1, stride=1,
                         searchable=False, theta_len=0))
    else:
        stem, search, pws, fc = DS.build_geoms(var.cfg)
        rows.append(dict(name="stem", ltype="conv", cin=3, cout=stem.cout,
                         k=3, ox=stem.ox, oy=stem.oy, stride=1,
                         searchable=False, theta_len=0))
        for g, pg in zip(search, pws):
            if fixed:
                tl, lt, srch = 0, "conv", False
            elif var.search_kind == "layerwise":
                tl, lt, srch = 2, "search", True
            else:
                tl, lt, srch = g.cout + 1, "search", True
            rows.append(dict(name=g.name, ltype=lt, cin=g.cin,
                             cout=g.cout, k=3, ox=g.ox, oy=g.oy,
                             stride=g.stride, searchable=srch, theta_len=tl))
            rows.append(dict(name=pg.name, ltype="pw", cin=pg.cin,
                             cout=pg.cout, k=1, ox=pg.ox, oy=pg.oy, stride=1,
                             searchable=False, theta_len=0))
        rows.append(dict(name="fc", ltype="fc", cin=fc.cin, cout=fc.cout,
                         k=1, ox=1, oy=1, stride=1, searchable=False,
                         theta_len=0))
    return rows
