"""Darkside layer-type supernet (ODiMO Sec. IV-C).

MobileNetV1-style network for the Darkside SoC, whose two CUs support
*different operations*: the 8-core RISC-V cluster runs standard (and
pointwise) convolutions, the DepthWise Engine (DWE) runs only depthwise
3x3. Each ``C_in == C_out`` position holds *both* alternatives in parallel
and a monotone per-channel gate decides, channel by channel, which CU
produces it.

Contiguity (Eq. 6): instead of independent per-channel logits, each
searchable layer owns ``C+1`` split-position logits ``theta``; with
``p = softmax(theta)`` the gate is ``g_c = P(split > c) = 1 - cumsum(p)_c``,
which is monotone non-increasing in ``c`` — so the channels mapped to the
cluster are always the leading contiguous block and no data marshaling is
ever needed on the SoC.

Search modes:

* ``dw_vs_conv``   — cluster runs a standard 3x3 conv, DWE a depthwise 3x3
  (the CIFAR search space of Sec. V);
* ``dw_vs_dwsep``  — DW vs DW-separable (DW + pointwise), the restricted
  ImageNet space of Sec. V-C1 (the two stages execute sequentially:
  DWE then cluster);
* ``layerwise``    — one shared gate per layer (the path-based DNAS
  baseline of Fig. 7-bottom).
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from . import layers as L
from .costs import (LayerGeom, darkside_cluster_cycles, darkside_dwe_cycles,
                    darkside_layer_lats)
from .kernels import matmul


@dataclass(frozen=True)
class DarksideConfig:
    name: str
    input_hw: int = 32
    stem_width: int = 8
    # (channels, dw_stride, pw_out) per searchable block
    blocks: tuple = ((8, 1, 16), (16, 2, 32), (32, 1, 32), (32, 2, 64),
                     (64, 1, 64), (64, 2, 128), (128, 1, 128))
    num_classes: int = 10
    width_mult: float = 1.0
    # 'dw_vs_conv' | 'dw_vs_dwsep' | 'layerwise' | 'fixed_conv'
    # (fixed_conv = plain all-standard-conv net, the Table II baseline)
    search_mode: str = "dw_vs_conv"


def _scaled(cfg: DarksideConfig):
    """Apply the width multiplier (Fig. 10) to all channel counts."""
    def s(c):
        return max(4, int(round(c * cfg.width_mult)))
    stem = s(cfg.stem_width)
    blocks = tuple((s(c), st, s(o)) for c, st, o in cfg.blocks)
    return stem, blocks


def build_geoms(cfg: DarksideConfig):
    """Static geometry: ``(stem, searchable, pointwise, fc)`` entries."""
    stem_w, blocks = _scaled(cfg)
    hw = cfg.input_hw
    stem = LayerGeom("stem", "conv", 3, stem_w, 3, hw, hw, 1, False)
    search, pws = [], []
    cin = stem_w
    for i, (c, st, pw_out) in enumerate(blocks):
        assert cin == c, f"block {i}: Cin {cin} != C {c} (searchable layers need Cin==Cout)"
        hw = math.ceil(hw / st)
        search.append(LayerGeom(f"blk{i}", "conv", c, c, 3, hw, hw, st, True))
        pws.append(LayerGeom(f"pw{i}", "pw", c, pw_out, 1, hw, hw, 1, False))
        cin = pw_out
    fc = LayerGeom("fc", "fc", cin, cfg.num_classes, 1, 1, 1, 1, False)
    return stem, search, pws, fc


def theta_paths(cfg: DarksideConfig):
    _, search, _, _ = build_geoms(cfg)
    return [g.name for g in search]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init(key, cfg: DarksideConfig) -> dict:
    stem, search, pws, fc = build_geoms(cfg)
    n_keys = 1 + 3 * len(search) + len(pws) + 1
    keys = iter(jax.random.split(key, n_keys))
    params = {
        "stem": {"w": L.conv_init(next(keys), 3, 3, stem.cout),
                 "bn": L.bn_init(stem.cout)}
    }
    for g in search:
        c = g.cout
        if cfg.search_mode == "fixed_conv":
            params[g.name] = {"bn": L.bn_init(c),
                              "w_conv": L.conv_init(next(keys), 3, c, c)}
            next(keys)
            next(keys)  # keep key schedule aligned across modes
            continue
        if cfg.search_mode == "layerwise":
            theta = jnp.zeros((2,), dtype=jnp.float32)
        else:
            theta = jnp.zeros((c + 1,), dtype=jnp.float32)
        blk = {"theta": theta, "bn": L.bn_init(c),
               "w_dw": L.dw_init(next(keys), c)}
        if cfg.search_mode == "dw_vs_dwsep":
            blk["w_pw_sep"] = L.conv_init(next(keys), 1, c, c)
            next(keys)  # keep key schedule aligned across modes
        else:
            blk["w_conv"] = L.conv_init(next(keys), 3, c, c)
            next(keys)
        params[g.name] = blk
    for g in pws:
        params[g.name] = {"w": L.conv_init(next(keys), 1, g.cin, g.cout),
                          "bn": L.bn_init(g.cout)}
    params["fc"] = L.fc_init(next(keys), fc.cin, fc.cout)
    return params


# ---------------------------------------------------------------------------
# Gates
# ---------------------------------------------------------------------------

def split_gate(theta: jnp.ndarray, c: int) -> jnp.ndarray:
    """Eq. 6 monotone gate: ``g_c`` = probability that channel ``c`` is
    produced by the *cluster* branch. ``theta: [C+1]`` split logits."""
    p = jax.nn.softmax(theta)
    return 1.0 - jnp.cumsum(p)[:c]


def ste_int8_dw(w: jnp.ndarray) -> jnp.ndarray:
    """Straight-through int8 for a depthwise ``[3, 3, C]`` weight."""
    from .kernels.fake_quant import ste_int8_rows
    flat = w.transpose(2, 0, 1).reshape(w.shape[-1], -1)
    return ste_int8_rows(flat).reshape(w.shape[-1], 3, 3).transpose(1, 2, 0)


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _search_block(x, p, g: LayerGeom, cfg: DarksideConfig, training: bool):
    c = g.cout
    if cfg.search_mode == "fixed_conv":
        y = L.conv2d(x, L.ste_int8(p["w_conv"]), g.stride)
        y, stats = L.batch_norm(y, p["bn"], training)
        lats = [darkside_cluster_cycles(float(c), g), jnp.float32(0.0)]
        return jax.nn.relu(y), stats, lats, "max", jnp.float32(c)
    if cfg.search_mode == "layerwise":
        gc = jnp.broadcast_to(jax.nn.softmax(p["theta"])[0], (c,))
    else:
        gc = split_gate(p["theta"], c)
    n_cluster = jnp.sum(gc)

    y_dw = L.dw_conv2d(x, ste_int8_dw(p["w_dw"]), g.stride)
    if cfg.search_mode == "dw_vs_dwsep":
        # DW always runs (on the DWE); the gated alternative adds a
        # pointwise on the cluster. Stages are sequential: DWE -> cluster.
        y_sep = L.conv2d(y_dw, L.ste_int8(p["w_pw_sep"]), 1)
        y = gc * y_sep + (1.0 - gc) * y_dw
        pw_geom = LayerGeom(g.name + "_pw", "pw", c, c, 1, g.ox, g.oy, 1)
        lats = [darkside_cluster_cycles(n_cluster, pw_geom),
                darkside_dwe_cycles(float(c), g)]
        combine = "sum"
    else:
        y_conv = L.conv2d(x, L.ste_int8(p["w_conv"]), g.stride)
        y = gc * y_conv + (1.0 - gc) * y_dw
        lats = darkside_layer_lats(n_cluster, c - n_cluster, g)
        combine = "max"
    y, stats = L.batch_norm(y, p["bn"], training)
    return jax.nn.relu(y), stats, lats, combine, n_cluster


def apply(params, x, cfg: DarksideConfig, training: bool):
    """Supernet forward.

    Returns ``(logits, new_bn_stats, per_layer)`` with ``per_layer`` a list
    of ``(name, lats [cluster, dwe], combine, n_cluster)`` covering *every*
    layer (fixed layers report their full channel count on the cluster).
    """
    stem, search, pws, fc = build_geoms(cfg)
    new_bn = {}
    per_layer = []

    h = L.conv2d(x, L.ste_int8(params["stem"]["w"]), 1)
    h, new_bn["stem"] = L.batch_norm(h, params["stem"]["bn"], training)
    h = jax.nn.relu(h)
    per_layer.append(("stem",
                      [darkside_cluster_cycles(float(stem.cout), stem),
                       jnp.float32(0.0)], "max", jnp.float32(stem.cout)))

    for g, pg in zip(search, pws):
        y, stats, lats, combine, n_cl = _search_block(
            h, params[g.name], g, cfg, training)
        new_bn[g.name] = stats
        per_layer.append((g.name, lats, combine, n_cl))

        h = L.conv2d(y, L.ste_int8(params[pg.name]["w"]), 1)
        h, new_bn[pg.name] = L.batch_norm(h, params[pg.name]["bn"], training)
        h = jax.nn.relu(h)
        per_layer.append((pg.name,
                          [darkside_cluster_cycles(float(pg.cout), pg),
                           jnp.float32(0.0)], "max", jnp.float32(pg.cout)))

    feat = L.global_avg_pool(h)
    logits = matmul(feat, L.ste_int8(params["fc"]["w"])) + params["fc"]["b"]
    per_layer.append(("fc",
                      [darkside_cluster_cycles(float(fc.cout), fc),
                       jnp.float32(0.0)], "max", jnp.float32(fc.cout)))
    return logits, new_bn, per_layer
