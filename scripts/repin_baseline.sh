#!/usr/bin/env bash
# Re-pin the absolute floors of rust/benches/native_train.baseline.json
# from a real CI bench artifact.
#
# Usage:
#   scripts/repin_baseline.sh path/to/BENCH_native_train.json [slack] [ci-run-id]
#
# Downloads of the BENCH_native_train artifact from a green CI run are
# the expected input. The script rewrites exactly the four *absolute*
# floors (threads1/threads4 train steps/sec, 1-/4-thread quantized
# evals/sec) to measured * slack (default 0.80 — CI runners vary run to
# run, so committed floors keep 20% headroom below a measured green
# run; the BENCH_CHECK gate then allows a further 10% below the floor).
# When a ci-run-id is given (the numeric id of the run the artifact was
# downloaded from, e.g. from the run's URL) it is recorded in the
# baseline note, so a re-pin is traceable to the exact green run that
# produced it. The machine-independent `_min` ratio floors carry
# acceptance criteria and are NEVER re-pinned from measurements — edit
# those by hand, with the criterion, or not at all.
set -euo pipefail

if [ $# -lt 1 ] || [ $# -gt 3 ]; then
    echo "usage: $0 path/to/BENCH_native_train.json [slack] [ci-run-id]" >&2
    exit 2
fi

src="$1"
slack="${2:-0.80}"
run_id="${3:-}"
dst="$(dirname "$0")/../rust/benches/native_train.baseline.json"

python3 - "$src" "$dst" "$slack" "$run_id" <<'PYEOF'
import json
import sys

src, dst, slack, run_id = sys.argv[1], sys.argv[2], float(sys.argv[3]), sys.argv[4]
rec = json.load(open(src))
base = json.load(open(dst))

ABSOLUTE = [
    "threads1_steps_per_sec",
    "threads4_steps_per_sec",
    "quantized_evals_per_sec_threads1",
    "quantized_evals_per_sec_threads4",
]

for key in ABSOLUTE:
    measured = rec[key]
    old = base[key]
    base[key] = round(measured * slack, 3)
    print(f"  {key}: {old} -> {base[key]}  (measured {measured:.3f} * {slack})")

tier = rec.get("qmatmul_tier", "unknown")
mins = ", ".join(k for k in base if k.endswith("_min"))
provenance = (
    f"CI run {run_id}" if run_id else "a CI run (id not recorded — pass it "
    "as the third argument next time)"
)
base["note"] = (
    "Floors for the BENCH_CHECK=1 gate: the job fails when a measured value "
    "drops more than 10% below its floor (< 0.9x). The four absolute floors "
    f"were re-pinned by scripts/repin_baseline.sh from {provenance}'s "
    f"BENCH_native_train.json (variant {rec.get('variant', '?')}, qmatmul "
    f"tier {tier}, simd_kernels={json.dumps(rec.get('simd_kernels'))}, "
    f"arch_kernels={json.dumps(rec.get('arch_kernels'))}) at "
    f"measured*{slack}. The _min ratio floors "
    f"({mins}) gate ratios measured inside one run, are machine-independent, "
    "carry the PR acceptance criteria, and are never re-pinned from "
    "measurements; qmatmul_arch_speedup_vs_simd_min is applied only when "
    "the bench record shows an arch kernel actually dispatched "
    "(qmatmul_arch_speedup_vs_simd present) — on runners without the CPU "
    "features the qmatmul_tier tag proves the fallback and the gate is "
    "skipped; matmul_packed_speedup_min gates the in-run packed-vs-unpacked "
    "f32 tier ratio at real layer-GEMM shapes."
)

with open(dst, "w") as f:
    json.dump(base, f, indent=2)
    f.write("\n")
print(f"wrote {dst}")
PYEOF
